"""Request-plane data model: per-request lifecycle accounting, the admission
queue, and a deterministic multi-tenant load generator.

A `Request` carries the four lifecycle stamps the SLO monitor judges
(enqueue -> admit -> first token -> finish) plus the derived per-request
metrics (queue wait, TTFT, TPOT, end-to-end latency, tokens/s). The
`LoadGenerator` is the serve-path analogue of the chaos injector's fault
schedule: arrivals are a pure function of ``(seed, step)``, so every run of a
scenario sees the same request stream — and the serve-plane fault kinds
(``tenant_flood``, ``heavy_prompt_skew``, ``slow_client_stall``) perturb the
*request mix*, not the probes (the request plane is the layer under test).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle accounting."""

    req_id: int
    tenant: int
    prompt: np.ndarray  # (P,) int32 token ids
    max_new_tokens: int
    enqueue_ts: float
    # per-token client-side delivery delay (slow-client modelling): every
    # generated token's delivery lags compute by this much, cumulatively
    client_stall_s: float = 0.0
    # engine-filled lifecycle stamps (engine clock; -1 = not reached)
    admit_ts: float = -1.0
    first_token_ts: float = -1.0
    finish_ts: float = -1.0
    start_index: int = -1  # absolute cache position of prompt[0]
    tokens_out: int = 0
    stall_s: float = 0.0  # accumulated client-stall folded into delivery
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def queue_wait(self) -> float:
        return max(0.0, self.admit_ts - self.enqueue_ts)

    @property
    def ttft(self) -> float:
        """Enqueue -> first delivered token (queue wait included: the SLO is
        the client's, and the client cannot see admission)."""
        return max(0.0, self.first_token_ts - self.enqueue_ts)

    @property
    def tpot(self) -> float:
        """Mean inter-token delivery time after the first token."""
        if self.tokens_out <= 1:
            return 0.0
        return max(0.0, (self.finish_ts - self.first_token_ts)
                   / (self.tokens_out - 1))

    @property
    def e2e(self) -> float:
        return max(0.0, self.finish_ts - self.enqueue_ts)

    @property
    def tokens_per_s(self) -> float:
        span = self.finish_ts - self.admit_ts
        return self.tokens_out / span if span > 0 else 0.0

    def record(self, step: int) -> Dict[str, float]:
        """The flat per-request record published to the request probe."""
        return {
            "req_id": self.req_id, "tenant": self.tenant, "step": step,
            "enqueue_ts": self.enqueue_ts, "admit_ts": self.admit_ts,
            "first_token_ts": self.first_token_ts,
            "finish_ts": self.finish_ts,
            "prompt_len": self.prompt_len, "tokens_out": self.tokens_out,
            "queue_wait": self.queue_wait, "ttft": self.ttft,
            "tpot": self.tpot, "e2e": self.e2e, "stall_s": self.stall_s,
        }


class RequestQueue:
    """FIFO admission queue with per-tenant depth accounting."""

    def __init__(self, max_depth: Optional[int] = None):
        self.max_depth = max_depth
        self._q: Deque[Request] = collections.deque()
        self.enqueued = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> bool:
        """Enqueue; returns False (and counts a rejection) when full."""
        if self.max_depth is not None and len(self._q) >= self.max_depth:
            self.rejected += 1
            return False
        self._q.append(req)
        self.enqueued += 1
        return True

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pop(self) -> Request:
        return self._q.popleft()

    def tenant_depths(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for r in self._q:
            out[r.tenant] = out.get(r.tenant, 0) + 1
        return out


class LoadGenerator:
    """Deterministic multi-tenant arrival process, indexed by engine step.

    ``arrivals(step, now, faults)`` is a pure function of ``(seed, step,
    faults)``: the base stream draws a Poisson arrival count at ``rate``
    requests per step, a tenant from ``tenants`` (normalised weights), a
    prompt length and a generation budget from their ranges. Serve-plane
    fault kinds perturb the draw:

    * ``tenant_flood``    — the flood tenant (tenant 0) arrives at
                            ``magnitude`` x its base share of the rate.
    * ``heavy_prompt_skew`` — prompt lengths scale by ``magnitude``
                            (clipped to ``prompt_len`` range's cap x mag).
    * ``slow_client_stall`` — new requests carry ``client_stall_s =
                            magnitude`` (seconds of client-side delay per
                            delivered token).
    """

    FLOOD_TENANT = 0

    def __init__(self, rate: float, num_requests: Optional[int] = None,
                 seed: int = 0, tenants: Sequence[float] = (0.5, 0.3, 0.2),
                 prompt_len: Tuple[int, int] = (4, 24),
                 max_new: Tuple[int, int] = (4, 16),
                 vocab_size: int = 256):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.num_requests = num_requests
        self.seed = int(seed)
        w = np.asarray(tenants, dtype=np.float64)
        self.tenants = w / w.sum()
        self.prompt_len = (int(prompt_len[0]), int(prompt_len[1]))
        self.max_new = (int(max_new[0]), int(max_new[1]))
        self.vocab_size = int(vocab_size)
        self.generated = 0

    @property
    def done(self) -> bool:
        return (self.num_requests is not None
                and self.generated >= self.num_requests)

    def _rng(self, step: int) -> np.random.Generator:
        # same per-step mixing constant as the chaos injector: arrivals are
        # reproducible from (seed, step) alone
        return np.random.default_rng(
            (self.seed * 9973 + step * 2654435761) % (2 ** 31))

    def _make(self, rng: np.random.Generator, now: float, tenant: int,
              plen_scale: float, stall_s: float) -> Request:
        lo, hi = self.prompt_len
        plen = int(rng.integers(lo, hi + 1))
        plen = max(1, min(int(round(plen * plen_scale)),
                          int(hi * max(plen_scale, 1.0))))
        prompt = rng.integers(1, self.vocab_size, size=plen,
                              dtype=np.int64).astype(np.int32)
        n_new = int(rng.integers(self.max_new[0], self.max_new[1] + 1))
        req = Request(req_id=self.generated, tenant=tenant, prompt=prompt,
                      max_new_tokens=n_new, enqueue_ts=now,
                      client_stall_s=stall_s)
        self.generated += 1
        return req

    def arrivals(self, step: int, now: float,
                 faults: Optional[Dict[str, float]] = None) -> List[Request]:
        """Requests arriving at ``step`` (stamped ``enqueue_ts = now``)."""
        if self.done:
            return []
        faults = faults or {}
        rng = self._rng(step)
        plen_scale = max(1.0, faults.get("heavy_prompt_skew", 0.0)) \
            if "heavy_prompt_skew" in faults else 1.0
        stall_s = float(faults.get("slow_client_stall", 0.0))
        out: List[Request] = []
        n_base = int(rng.poisson(self.rate))
        for _ in range(n_base):
            tenant = int(rng.choice(len(self.tenants), p=self.tenants))
            out.append(self._make(rng, now, tenant, plen_scale, stall_s))
            if self.done:
                return out
        flood = faults.get("tenant_flood", 0.0)
        if flood > 1.0:
            extra_rate = self.rate * self.tenants[self.FLOOD_TENANT] \
                * (flood - 1.0)
            for _ in range(int(rng.poisson(extra_rate))):
                out.append(self._make(rng, now, self.FLOOD_TENANT,
                                      plen_scale, stall_s))
                if self.done:
                    return out
        return out
