"""Run one labelled scenario through the Session API and keep the evidence.

Each cell of the scenario matrix is one monitored run:

    scenario (chaos schedule) x mode (batch | stream) x EvalConfig (detector)

Two workload shapes, both observed through `Session.observe_step_fn` (the
zero-instrumentation contract — the step code never changes):

* ``train``: a jitted synthetic train step plus a registered all-reduce
  schedule, so every probe layer produces events. Cheap enough that the full
  matrix runs on a laptop CPU; the detectors only see probe events, so
  detection quality is workload-size-independent (the faults are injected at
  the probe hooks, exactly as in the paper's testbed).
* ``serve``: the real reduced-GPT-2 decode loop (`repro.serve.engine`), one
  monitored step per generated token.
* ``request``: the continuous-batching engine under a deterministic
  multi-tenant load (`repro.serve.continuous` on a `VirtualClock`), judged
  by the SLO plane rather than the GMM detectors — serve-path faults
  perturb the *request mix* and are scored via `slo_breach_metrics`.

The run's first ``clean_fraction`` steps are fault-free by scenario
construction; stream mode warms up there, batch mode gets a matching holdoff
so its final refit trains on the same clean prefix. Metrics are scored only
on the live region after it.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chaos import Fault, Scenario
from repro.eval.metrics import (DetectionMetrics, DiagnosisMetrics,
                                SLOBreachMetrics, debounce,
                                detection_metrics, diagnosis_metrics,
                                slo_breach_metrics, step_predictions)
from repro.session import DetectorSpec, MonitorSpec, Session
from repro.session.report import MonitorReport
from repro.stream.incidents import IncidentMatch, match_incidents

EVAL_PROBES = ["xla", "operator", "collective", "device", "step"]

# request-workload cell: SLO targets and load shape, tuned so the nominal
# arrival process never breaches (the serve_clean_control scenario must
# close ZERO breach incidents) while each serve fault kind breaches its
# signature metric well clear of the target
SERVE_SLO = {"ttft_s": 0.4, "tpot_s": 0.08, "queue_wait_s": 0.2,
             "queue_depth": 8, "min_breaches": 6, "gap_s": 0.5,
             "close_after_s": 0.5}
SERVE_LOAD = {"rate": 0.18, "prompt_len": (4, 12), "max_new": (4, 8)}
SERVE_SLOTS = 4
SERVE_DT = 0.02  # virtual seconds per engine step
# breach rows lag the burst by a queue-drain, not just a flush interval
SERVE_GRACE_STEPS = 40

# a GPT-2-class DP all-reduce schedule for the synthetic workload (message
# sizes in the gradient-bucket range), so the collective probe has traffic
_FAKE_HLO = "\n".join(
    f"  %ar{i} = f32[{1 << (12 + i)}]{{0}} all-reduce(%g{i}), "
    "replica_groups={}" for i in range(8))


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """One detector configuration of the matrix (spec fields + scoring)."""

    name: str = "default"
    backend: str = "gmm"       # detector-registry family (the bake-off axis)
    # root-cause attribution is calibrated in GMM log-density nats (the
    # Diagnoser's min_mean_deficit floor); the other families' score scales
    # are not nats, so their deficits sit arbitrarily around the floor and
    # would diagnose (or not) on host timing noise. Family configs run
    # detection-only; blamed-kind quality is a GMM column.
    diagnosis: bool = True
    n_components: int = 3
    contamination: float = 0.02
    min_events: int = 32
    warm_start: bool = True
    sweep_every: int = 60     # batch refit cadence
    flush_every: int = 20     # stream tick cadence ("window" step width)
    horizon_s: float = 300.0  # stream sliding-window span
    device_interval: float = 0.005
    step_sleep: float = 0.002  # host pacing so device telemetry accumulates
    vote: float = 0.5          # per-layer per-step majority-vote fraction
    min_run: int = 3           # debounce: required consecutive flagged steps
    grace_steps: int = 20      # detection-lag allowance for time-to-detect

    def detector_spec(self, holdoff_steps: int, seed: int) -> DetectorSpec:
        return DetectorSpec(
            backend=self.backend,
            n_components=self.n_components,
            contamination=self.contamination,
            min_events=self.min_events, seed=seed,
            sweep_every=self.sweep_every, holdoff_steps=holdoff_steps,
            warm_start=self.warm_start, flush_every=self.flush_every,
            horizon_s=self.horizon_s,
            # synthetic runs compress a "fleet minute" into ~1 wall second,
            # so incident clustering runs at a matching time scale
            incident_gap_s=0.25, incident_close_after_s=0.25, min_flags=5,
            # scoring compares flags against per-step ground truth, so
            # sweeps must publish at the cadence point that snapshotted
            # them — the thread executor's staleness would smear flags
            # across label windows and make cells runner-load dependent
            executor="inline")


@dataclasses.dataclass
class ScenarioRun:
    """One matrix cell: the report plus everything needed to score it."""

    scenario: Scenario
    mode: str
    config: EvalConfig
    n_steps: int
    eval_start: int
    labels: np.ndarray
    windows: List[Tuple[int, int]]
    faults: List[Fault]
    step_ts: np.ndarray
    report: MonitorReport
    wall_s: float

    def predictions(self) -> Dict[str, np.ndarray]:
        return step_predictions(self.report.detections, self.n_steps,
                                vote=self.config.vote)

    def metrics(self) -> DetectionMetrics:
        pred = debounce(self.predictions()["any"], self.config.min_run)
        return detection_metrics(
            pred, self.labels, self.windows,
            eval_start=self.eval_start, grace_steps=self.config.grace_steps,
            step_ts=self.step_ts)

    def incident_match(self, grace_steps: int = 4) -> Optional[IncidentMatch]:
        if self.mode != "stream" or not self.windows:
            return None
        return match_incidents(self.report.incidents, self.windows,
                               grace_steps=grace_steps)

    def slo_metrics(self, grace_steps: int = SERVE_GRACE_STEPS
                    ) -> SLOBreachMetrics:
        """Request-plane scoring: breach incidents vs serve fault windows."""
        return slo_breach_metrics(self.report.incidents, self.windows,
                                  grace_steps=grace_steps)

    def diagnosis_metrics(self, grace_steps: Optional[int] = None
                          ) -> DiagnosisMetrics:
        """Blamed-kind / blamed-node / action-match scoring of the report's
        diagnoses against the injected schedule (single-node runs: every
        fault perturbs node 0; request runs: the flood tenant is tenant 0).
        The step layer's detections double as the collector-clock step
        mapping for step-less (device) diagnoses."""
        from repro.core.events import Layer

        if grace_steps is None:
            grace_steps = (SERVE_GRACE_STEPS
                           if self.scenario.workload == "request" else 4)

        clock = None
        det = self.report.detections.get(Layer.STEP)
        if det is not None and getattr(det, "ts", None) is not None:
            clock = (np.asarray(det.steps), np.asarray(det.ts))
        return diagnosis_metrics(self.report.diagnoses, self.faults,
                                 grace_steps=grace_steps, fault_nodes=(0,),
                                 step_clock=clock)


# -- workloads ----------------------------------------------------------------

@jax.jit
def _synth_step(x):
    # a few ms of real compute per step: long enough that host scheduler
    # jitter (absolute, ~100s of us) is small relative to the baseline
    # duration in log space, short enough that the full matrix stays cheap
    for _ in range(4):
        x = (x @ jnp.sin(x)) / jnp.maximum(jnp.abs(x).sum(), 1.0)
    return x


@functools.lru_cache(maxsize=1)
def _serve_parts():
    """Reduced-GPT-2 decode-step factory, built once per process."""
    from repro.config import get_arch, reduced
    from repro.models.model import Runtime, init_decode_caches, init_params
    from repro.serve.engine import make_decode_step

    cfg = reduced(get_arch("gpt2"))
    rt = Runtime(mesh=None, compute_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_decode_step(cfg, rt), donate_argnums=(2,))
    return cfg, params, step, functools.partial(init_decode_caches, cfg)


def run_scenario(scenario: Scenario, mode: str,
                 config: Optional[EvalConfig] = None,
                 n_steps: int = 240, seed: int = 0) -> ScenarioRun:
    """Execute one scenario under one mode/config; returns the scored run."""
    cfg = config or EvalConfig()
    if mode not in ("batch", "stream"):
        raise ValueError(f"mode must be batch|stream, got {mode!r}")
    eval_start = int(n_steps * scenario.clean_fraction)
    injector = scenario.injector(n_steps)
    labels = injector.labels(n_steps)
    if scenario.workload == "request":
        # the request plane is SLO-thresholded, not GMM-modelled: only the
        # request probe attaches and the detector spec is irrelevant
        spec = MonitorSpec(mode=mode, probes=["request"],
                           slo=dict(SERVE_SLO), governor=False, seed=seed)
        runner = _run_request_steps
    else:
        spec = MonitorSpec(
            mode=mode, probes=list(EVAL_PROBES),
            probe_options={"device": {"interval": cfg.device_interval}},
            detector=cfg.detector_spec(holdoff_steps=n_steps - eval_start,
                                       seed=seed),
            diagnosis=cfg.diagnosis, governor=False, seed=seed)
        runner = (_run_train_steps if scenario.workload == "train"
                  else _run_serve_steps)
    session = Session(spec)
    t0 = time.perf_counter()
    step_ts = runner(session, injector, n_steps, eval_start, cfg, seed)
    wall = time.perf_counter() - t0
    return ScenarioRun(
        scenario=scenario, mode=mode, config=cfg, n_steps=n_steps,
        eval_start=eval_start, labels=labels, windows=injector.windows(),
        faults=list(injector.faults), step_ts=step_ts,
        report=session.result(), wall_s=wall)


def _drive(session: Session, injector, n_steps: int, eval_start: int,
           cfg: EvalConfig, one_step) -> np.ndarray:
    """The shared monitored loop: inject, step, hand cadence to the session.
    Stream warmup fires exactly at the end of the clean prefix."""
    col = session.collector
    step_ts = np.zeros(n_steps)
    t0 = time.perf_counter()
    stream = session.spec.mode == "stream"
    for s in range(n_steps):
        if stream and s == eval_start:
            session.warmup()
        injector.apply(s, col)
        step_ts[s] = time.perf_counter() - t0
        one_step(s)
        if cfg.step_sleep:
            time.sleep(cfg.step_sleep)
        if not stream or s >= eval_start:
            session.on_step(s)
    injector.clear(col)
    time.sleep(3 * cfg.device_interval)  # last device samples land
    return step_ts


def _run_train_steps(session: Session, injector, n_steps: int,
                     eval_start: int, cfg: EvalConfig, seed: int
                     ) -> np.ndarray:
    x0 = jnp.ones((192, 192)) * (1.0 + 0.01 * seed)
    jax.block_until_ready(_synth_step(x0))  # compile outside the probes
    with session.monitoring():
        session.collector["collective"].register_compiled(_FAKE_HLO)
        fn = session.observe_step_fn(_synth_step, sample_args=(x0,),
                                     mem_gb=0.5)
        state = {"x": x0}

        def one_step(s):
            state["x"] = fn(state["x"])

        return _drive(session, injector, n_steps, eval_start, cfg, one_step)


def _run_serve_steps(session: Session, injector, n_steps: int,
                     eval_start: int, cfg: EvalConfig, seed: int
                     ) -> np.ndarray:
    model_cfg, params, step, make_caches = _serve_parts()
    batch_size = 2
    caches = make_caches(batch_size, n_steps + 1)
    tok0 = jnp.ones((batch_size, 1), jnp.int32)
    # compile outside the probes (fresh caches afterwards: donated)
    logits, _ = step(params, {"tokens": tok0},
                     make_caches(batch_size, n_steps + 1), jnp.int32(0))
    jax.block_until_ready(logits)
    state = {"tok": tok0, "caches": caches}
    with session.monitoring():
        fn = session.observe_step_fn(
            step, sample_args=(params, {"tokens": tok0}, caches,
                               jnp.int32(0)),
            mem_gb=0.5)

        def one_step(s):
            logits, state["caches"] = fn(params, {"tokens": state["tok"]},
                                         state["caches"], jnp.int32(s))
            nxt = jnp.argmax(
                logits[:, -1, : model_cfg.vocab_size], axis=-1)
            state["tok"] = nxt.astype(jnp.int32)[:, None]

        return _drive(session, injector, n_steps, eval_start, cfg, one_step)


@functools.lru_cache(maxsize=1)
def _request_parts():
    """Reduced-GPT-2 config/params for the continuous-batching workload."""
    from repro.config import get_arch, reduced
    from repro.models.model import Runtime, init_params

    cfg = reduced(get_arch("gpt2"))
    rt = Runtime(mesh=None, compute_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rt, params


def _run_request_steps(session: Session, injector, n_steps: int,
                       eval_start: int, cfg: EvalConfig, seed: int
                       ) -> np.ndarray:
    """Continuous-batching engine under deterministic load; serve faults
    perturb the arrival mix via ``injector.serve_faults``. The engine runs
    a `VirtualClock`, so every latency is a pure function of scheduling and
    the cell is reproducible bit-for-bit from ``seed``."""
    from repro.serve import (ContinuousBatchingEngine, LoadGenerator,
                             VirtualClock)

    model_cfg, rt, params = _request_parts()
    eng = ContinuousBatchingEngine(
        model_cfg, rt, params, slots=SERVE_SLOTS, max_len=n_steps + 96,
        seed=seed, clock=VirtualClock(SERVE_DT), dtype=jnp.float32)
    load = LoadGenerator(rate=SERVE_LOAD["rate"], seed=seed,
                         prompt_len=SERVE_LOAD["prompt_len"],
                         max_new=SERVE_LOAD["max_new"],
                         vocab_size=model_cfg.vocab_size)
    with session.monitoring():
        eng.run(load, n_steps=n_steps,
                faults_for_step=injector.serve_faults,
                on_step=lambda s: session.on_step(s), drain=False)
    return np.arange(n_steps, dtype=np.float64) * SERVE_DT
