"""Scenario-matrix evaluation: scores the monitoring stack against chaos
ground truth (paper §V, Table-I-style results for THIS repo's detectors).

The subsystem closes the loop between fault injection (`repro.core.chaos`
scenarios) and detection (`repro.session.Session`):

    scenario --FaultInjector--> monitored run --MonitorReport-->
        step predictions --metrics--> precision/recall/F1, time-to-detect,
        false-alarm rate, diagnosis accuracy (blamed kind / node / action)
        --matrix--> scenario_matrix.json + leaderboard.md

Entry points:
    python -m repro.launch.evaluate --scenarios all --out results/eval/
    run_matrix(...)                       # library use
    run_scenario(scenario, mode, config)  # one cell

See docs/evaluation.md for the methodology and the documented false-alarm
ceiling of the clean-control scenario.
"""
from repro.eval.metrics import (DetectionMetrics,  # noqa: F401
                                DiagnosisMetrics, debounce,
                                detection_metrics, diagnosis_metrics,
                                step_predictions, window_kinds)
from repro.eval.runner import EvalConfig, ScenarioRun, run_scenario  # noqa: F401
from repro.eval.matrix import (CONFIG_GRID, FAR_CEILING,  # noqa: F401
                               render_leaderboard, run_matrix, save_matrix)
