"""The scenario matrix: scenarios x modes x detector configs -> artifacts.

`run_matrix` executes every requested cell through `run_scenario` and
returns one machine-readable dict; `save_matrix` writes it as
``scenario_matrix.json`` next to a rendered ``leaderboard.md``. CI runs the
smoke subset and holds the clean-control scenario's false-alarm rate below
`FAR_CEILING` — the detection-quality analogue of a perf-regression gate.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.chaos import get_scenario
from repro.eval.runner import EvalConfig, run_scenario

# documented false-alarm ceiling for the clean-control scenario (step-level
# false-alarm rate over the live region, either mode) — see
# docs/evaluation.md#false-alarm-ceiling before changing it. Typical runs
# sit at 0-8%; the ceiling leaves room for host timing noise (the latency
# layers measure REAL wall time, and CI machines have noisy neighbours).
FAR_CEILING = 0.15

MODES = ("batch", "stream")

# the named config axis: detector variants the matrix sweeps. "default" is
# the tuned GMM operating point; the single-knob variants (components K,
# window width, warm-start) keep regressions attributable, and the family
# configs put every registered detector backend on the same scenarios for
# the bake-off.
CONFIG_GRID: Dict[str, EvalConfig] = {
    c.name: c for c in (
        EvalConfig(name="default"),
        EvalConfig(name="k2", n_components=2),
        EvalConfig(name="k5", n_components=5),
        EvalConfig(name="wide_window", flush_every=40, sweep_every=120),
        EvalConfig(name="narrow_window", flush_every=10, sweep_every=30),
        EvalConfig(name="no_warm_start", warm_start=False),
        EvalConfig(name="isoforest", backend="isoforest", diagnosis=False),
        EvalConfig(name="mad", backend="mad", diagnosis=False),
        EvalConfig(name="spectral", backend="spectral", diagnosis=False),
    )
}

# the bake-off slice: one config per detector family, identical everywhere
# else, so per-cell wins measure the family and not the tuning
BAKEOFF_CONFIGS = ("default", "isoforest", "mad", "spectral")


def run_matrix(scenarios: Sequence[str], modes: Sequence[str] = MODES,
               configs: Sequence[str] = ("default",), n_steps: int = 240,
               seed: int = 0, progress=None) -> Dict[str, object]:
    """Run every (scenario, mode, config) cell; returns the matrix dict."""
    rows: List[Dict[str, object]] = []
    for name in scenarios:
        scenario = get_scenario(name)
        for mode in modes:
            for cname in configs:
                cfg = CONFIG_GRID[cname] if isinstance(cname, str) else cname
                run = run_scenario(scenario, mode, cfg, n_steps=n_steps,
                                   seed=seed)
                row = _row(run)
                rows.append(row)
                if progress is not None:
                    progress(row)
    return {
        "n_steps": n_steps,
        "seed": seed,
        "modes": list(modes),
        "configs": {c: _config_json(CONFIG_GRID[c]) for c in configs
                    if isinstance(c, str) and c in CONFIG_GRID},
        "far_ceiling": FAR_CEILING,
        "rows": rows,
        "winners": crown_winners(rows),
    }


def _config_json(cfg: EvalConfig) -> Dict[str, object]:
    import dataclasses

    return dataclasses.asdict(cfg)


def _detect_cost_ms(run) -> Optional[float]:
    """Per-window detection cost (ms) from the report's overhead section.

    Stream cells report the monitor's own ``detect_ms_per_tick`` (one tick
    = one window); batch cells derive it from the detection executor's
    busy-time over completed sweeps. None when the cell never swept."""
    overhead = run.report.overhead or {}
    stream = overhead.get("stream") or {}
    cost = stream.get("detect_ms_per_tick")
    if cost is None:
        plane = overhead.get("detect_plane") or {}
        completed = plane.get("completed") or 0
        if completed:
            cost = 1e3 * float(plane.get("busy_seconds", 0.0)) / completed
    return None if cost is None else round(float(cost), 3)


def _row(run) -> Dict[str, object]:
    m = run.metrics()
    row: Dict[str, object] = {
        "scenario": run.scenario.name,
        "workload": run.scenario.workload,
        "kinds": list(run.scenario.kinds),
        "expected_layers": list(run.scenario.expected_layers),
        "mode": run.mode,
        "config": run.config.name,
        "detector": run.config.backend,
        "detect_ms_per_window": _detect_cost_ms(run),
        "eval_start": run.eval_start,
        "fault_windows": [list(w) for w in run.windows],
        "metrics": m.to_json(),
        "layers": {name: {"anomaly_rate": ls.anomaly_rate,
                          "events": ls.events,
                          "first_flag_ts": ls.first_flag_ts}
                   for name, ls in run.report.layers.items()},
        "wall_s": round(run.wall_s, 2),
    }
    im = run.incident_match()
    if im is not None:
        row["incidents"] = {"count": len(run.report.incidents),
                            **im.to_json()}
    if run.config.diagnosis:
        dm = run.diagnosis_metrics()
        row["diagnosis"] = {
            "kinds": [d.fault_kind for d in run.report.diagnoses],
            "actions": [d.action.kind for d in run.report.diagnoses],
            **dm.to_json()}
    if run.scenario.workload == "request":
        row["slo"] = run.slo_metrics().to_json()
    return row


def clean_control_far(matrix: Dict[str, object]) -> Optional[float]:
    """Worst clean-control false-alarm rate across modes/configs (None when
    the scenario was not part of the matrix)."""
    fars = [r["metrics"]["false_alarm_rate"] for r in matrix["rows"]
            if r["scenario"] == "clean_control"]
    return max(fars) if fars else None


def clean_control_diagnoses(matrix: Dict[str, object]) -> Optional[int]:
    """Total diagnoses emitted on the clean-control scenario across
    modes/configs — the no-false-diagnosis gate holds this at zero (None
    when the scenario was not part of the matrix)."""
    counts = [r["diagnosis"]["diagnoses_total"] for r in matrix["rows"]
              if r["scenario"] == "clean_control" and "diagnosis" in r]
    return sum(counts) if counts else None


def serve_clean_breaches(matrix: Dict[str, object]) -> Optional[int]:
    """Total SLO-breach incidents on the serve clean control across
    modes — the request-plane no-false-page gate holds this at zero (None
    when the scenario was not part of the matrix)."""
    counts = [r["slo"]["incidents_total"] for r in matrix["rows"]
              if r["scenario"] == "serve_clean_control" and "slo" in r]
    return sum(counts) if counts else None


def serve_breach_recall(matrix: Dict[str, object]) -> Optional[float]:
    """Mean breach-incident recall over the FAULTED serve cells (None when
    none is present): did every serve fault window raise an incident?"""
    recalls = [r["slo"]["recall"] for r in matrix["rows"]
               if "slo" in r and r["slo"]["windows_total"] > 0]
    return float(sum(recalls) / len(recalls)) if recalls else None


def mean_kind_accuracy(matrix: Dict[str, object]) -> Optional[float]:
    """Mean blamed-kind accuracy over the FAULTED cells (None when no
    faulted scenario is present). Cells that produced no diagnoses on a
    faulted run count as 0; clean-control cells are excluded — a spurious
    clean diagnosis is already caught by the clean_control_diagnoses gate
    and must not be double-counted here."""
    accs = [r["diagnosis"]["kind_accuracy"] for r in matrix["rows"]
            if "diagnosis" in r
            and r["diagnosis"]["windows_total"] > 0
            and r["diagnosis"]["kind_accuracy"] is not None]
    return float(sum(accs) / len(accs)) if accs else None


# -- per-cell winners ---------------------------------------------------------

def _cell_quality(row: Dict[str, object]) -> tuple:
    """Ranking key within a (fault kind, mode) cell: quality first (F1 to
    4 places — ties at that resolution are noise), then cheaper detection
    (unknown cost ranks below any measured cost)."""
    f1 = row["metrics"]["f1"] or 0.0
    cost = row.get("detect_ms_per_window")
    return (round(float(f1), 4), -(float("inf") if cost is None else cost))


def _winner_entry(row: Dict[str, object]) -> Dict[str, object]:
    m = row["metrics"]
    return {"detector": row.get("detector", "gmm"),
            "config": row["config"],
            "scenario": row["scenario"],
            "f1": m["f1"],
            "recall": m["recall"],
            "false_alarm_rate": m["false_alarm_rate"],
            "detect_ms_per_window": row.get("detect_ms_per_window")}


def crown_winners(rows: List[Dict[str, object]]
                  ) -> List[Dict[str, object]]:
    """The bake-off verdict: per fault-kind x mode cell, the best detector
    family (quality-first, detection cost as the tiebreak).

    Request-workload cells are excluded — the SLO plane thresholds them
    without any detector family in the loop. Within a cell each family is
    first reduced to its best row (families can enter under several
    configs), then families compete; the runner-up is kept so the margin
    is visible in the leaderboard."""
    cells: Dict[tuple, List[Dict[str, object]]] = {}
    for row in rows:
        if row["workload"] == "request" or not row["metrics"]["faults_total"]:
            continue
        for kind in row["kinds"]:
            cells.setdefault((kind, row["mode"]), []).append(row)
    winners: List[Dict[str, object]] = []
    for (kind, mode) in sorted(cells):
        best_by_family: Dict[str, Dict[str, object]] = {}
        for row in cells[(kind, mode)]:
            fam = row.get("detector", "gmm")
            cur = best_by_family.get(fam)
            if cur is None or _cell_quality(row) > _cell_quality(cur):
                best_by_family[fam] = row
        ranked = sorted(best_by_family.values(), key=_cell_quality,
                        reverse=True)
        winners.append({
            "kind": kind,
            "mode": mode,
            "winner": _winner_entry(ranked[0]),
            "runner_up": (_winner_entry(ranked[1])
                          if len(ranked) > 1 else None),
            "families": {fam: _winner_entry(r)
                         for fam, r in sorted(best_by_family.items())},
        })
    return winners


# -- rendering ----------------------------------------------------------------

def _fmt(x, pct: bool = False) -> str:
    if x is None:
        return "—"
    return f"{100 * x:.1f}%" if pct else f"{x:.1f}"


def render_leaderboard(matrix: Dict[str, object]) -> str:
    """The scenario matrix as a markdown leaderboard (one row per cell)."""
    lines = [
        "# Scenario-matrix leaderboard",
        "",
        f"{matrix['n_steps']} steps/run, seed {matrix['seed']}; metrics are "
        "step-level over the live region (see docs/evaluation.md). "
        f"Clean-control false-alarm ceiling: {100 * matrix['far_ceiling']:.0f}%.",
        "",
        "| scenario | workload | mode | config | detector | precision "
        "| recall | F1 | FAR | TTD (steps) | detect ms/win | faults hit "
        "| diag | kind acc | action match |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    rows = sorted(matrix["rows"],
                  key=lambda r: (r["scenario"], r["mode"], r["config"]))
    for r in rows:
        m = r["metrics"]
        faulty = bool(m["faults_total"])
        faults = (f"{m['faults_detected']}/{m['faults_total']}"
                  if faulty else "—")
        # P/R/F1 are vacuous without labelled-anomalous steps: FAR is the
        # clean-control scenario's headline number
        prf = [_fmt(m[k] if faulty else None, pct=True)
               for k in ("precision", "recall", "f1")]
        dg = r.get("diagnosis", {})
        cost = r.get("detect_ms_per_window")
        lines.append(
            f"| {r['scenario']} | {r['workload']} | {r['mode']} "
            f"| {r['config']} | {r.get('detector', 'gmm')} "
            f"| {prf[0]} | {prf[1]} | {prf[2]} "
            f"| {_fmt(m['false_alarm_rate'], pct=True)} "
            f"| {_fmt(m['ttd_steps'])} "
            f"| {'—' if cost is None else f'{cost:.2f}'} | {faults} "
            f"| {dg.get('diagnoses_total', '—')} "
            f"| {_fmt(dg.get('kind_accuracy'), pct=True)} "
            f"| {_fmt(dg.get('action_match_rate'), pct=True)} |")
    winners = matrix.get("winners") or []
    if winners:
        lines += [
            "",
            "## Per-cell winners",
            "",
            "Best detector family per fault-kind x mode cell; quality "
            "(F1) first, per-window detection cost breaks ties.",
            "",
            "| fault kind | mode | winner | F1 | FAR | detect ms/win "
            "| runner-up |",
            "|---|---|---|---|---|---|---|",
        ]
        for w in winners:
            win, ru = w["winner"], w["runner_up"]
            cost = win["detect_ms_per_window"]
            ru_txt = ("—" if ru is None else
                      f"{ru['detector']} ({_fmt(ru['f1'], pct=True)})")
            lines.append(
                f"| {w['kind']} | {w['mode']} | **{win['detector']}** "
                f"| {_fmt(win['f1'], pct=True)} "
                f"| {_fmt(win['false_alarm_rate'], pct=True)} "
                f"| {'—' if cost is None else f'{cost:.2f}'} "
                f"| {ru_txt} |")
    far = clean_control_far(matrix)
    if far is not None:
        verdict = "PASS" if far < matrix["far_ceiling"] else "FAIL"
        lines += ["", f"Clean-control FAR: {100 * far:.1f}% "
                      f"(ceiling {100 * matrix['far_ceiling']:.0f}%) — "
                      f"**{verdict}**"]
    n_diag = clean_control_diagnoses(matrix)
    if n_diag is not None:
        verdict = "PASS" if n_diag == 0 else "FAIL"
        lines += [f"Clean-control diagnoses: {n_diag} (must be 0) — "
                  f"**{verdict}**"]
    acc = mean_kind_accuracy(matrix)
    if acc is not None:
        lines += [f"Mean blamed-kind accuracy over faulted cells: "
                  f"{100 * acc:.1f}%"]
    n_breach = serve_clean_breaches(matrix)
    if n_breach is not None:
        verdict = "PASS" if n_breach == 0 else "FAIL"
        lines += [f"Serve clean-control SLO-breach incidents: {n_breach} "
                  f"(must be 0) — **{verdict}**"]
    br = serve_breach_recall(matrix)
    if br is not None:
        lines += [f"Serve fault-window breach recall: {100 * br:.1f}%"]
    return "\n".join(lines) + "\n"


def save_matrix(matrix: Dict[str, object], out_dir: str) -> Dict[str, str]:
    """Write scenario_matrix.json + leaderboard.md under ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {"matrix": os.path.join(out_dir, "scenario_matrix.json"),
             "leaderboard": os.path.join(out_dir, "leaderboard.md")}
    with open(paths["matrix"], "w") as f:
        json.dump(matrix, f, indent=1, default=float)
    with open(paths["leaderboard"], "w") as f:
        f.write(render_leaderboard(matrix))
    return paths
