"""Golden anomaly-detection fixtures: tiny deterministic labelled windows.

One case per (non-serve) fault kind: a clean training prefix plus a scoring
window whose tail is a fault burst, generated from a seeded RNG so the same
seed always yields byte-identical events. The generator
(`tools/make_detector_fixtures.py`) runs every registered *batch* detector
family over these cases and commits the resulting per-row flag masks to
``tests/golden/detector_fixtures.json``; the conformance suite regenerates
the masks in-process and diffs them against the committed golden file, so a
behaviour change in any family is a visible diff, not a silent drift.

The bursts are sized like the chaos injector's (docs/evaluation.md): well
clear of clean jitter in the layer's own feature space, so every family is
expected to catch most of the burst while staying quiet on the clean case.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Tuple

import numpy as np

from repro.core.events import Event, Layer

# fixture axis: fault kind -> the layer whose window carries the burst
FIXTURE_KINDS: Dict[str, Layer] = {
    "op_latency": Layer.OPERATOR,
    "net_latency": Layer.COLLECTIVE,
    "hw_contention": Layer.DEVICE,
    "mem_leak": Layer.DEVICE,
}
TRAIN_ROWS = 240
WINDOW_ROWS = 120
BURST_ROWS = 24  # the window's tail rows carry the fault

_OPS = (("matmul", 800e-6, 1 << 22), ("layernorm", 120e-6, 1 << 18),
        ("softmax", 200e-6, 1 << 19))


def _op_events(rng: np.random.Generator, n: int, step0: int,
               slow: np.ndarray) -> List[Event]:
    """Operator-layer rows: per-name lognormal durations around fixed
    medians; ``slow`` multiplies the affected rows' durations."""
    out: List[Event] = []
    ts = 0.0
    for i in range(n):
        name, base, size = _OPS[i % len(_OPS)]
        dur = base * float(np.exp(rng.normal(0.0, 0.08))) * float(slow[i])
        ts += 1e-3
        out.append(Event(Layer.OPERATOR, name, ts=ts, dur=dur,
                         size=float(size), step=step0 + i // len(_OPS)))
    return out


def _coll_events(rng: np.random.Generator, n: int, step0: int,
                 slow: np.ndarray) -> List[Event]:
    """Collective rows: one all-reduce per step; a slowdown stretches dur,
    which also collapses the log-bandwidth feature."""
    out: List[Event] = []
    ts = 0.0
    for i in range(n):
        dur = 500e-6 * float(np.exp(rng.normal(0.0, 0.08))) * float(slow[i])
        ts += 1e-3
        out.append(Event(Layer.COLLECTIVE, "all_reduce", ts=ts, dur=dur,
                         size=float(4 << 20), step=step0 + i))
    return out


def _device_events(rng: np.random.Generator, n: int, step0: int,
                   util: np.ndarray, mem: np.ndarray, power: np.ndarray,
                   temp: np.ndarray) -> List[Event]:
    out: List[Event] = []
    for i in range(n):
        out.append(Event(
            Layer.DEVICE, "device0", ts=1e-3 * (i + 1), step=step0 + i,
            meta={"util": float(util[i]), "mem_gb": float(mem[i]),
                  "power_w": float(power[i]), "temp_c": float(temp[i])}))
    return out


def _device_clean(rng: np.random.Generator, n: int) -> Tuple[np.ndarray, ...]:
    return (np.clip(rng.normal(60.0, 3.0, n), 0, 100),
            rng.normal(4.0, 0.1, n),
            rng.normal(150.0, 5.0, n),
            rng.normal(55.0, 1.5, n))


def fixture_case(kind: str, seed: int = 0
                 ) -> Tuple[List[Event], List[Event], np.ndarray, Layer]:
    """One labelled case: (train_events, window_events, truth_mask, layer).

    ``kind`` is a `FIXTURE_KINDS` key or ``"clean"`` (operator-layer window
    with no burst; truth all-False). The truth mask marks the window rows
    perturbed by the burst."""
    layer = FIXTURE_KINDS.get(kind, Layer.OPERATOR)
    # zlib.crc32, not hash(): per-kind streams must not depend on
    # PYTHONHASHSEED or the golden file regenerates differently per process
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(kind.encode())]))
    truth = np.zeros(WINDOW_ROWS, dtype=bool)
    if kind != "clean":
        truth[-BURST_ROWS:] = True
    ones_train = np.ones(TRAIN_ROWS)
    if layer == Layer.DEVICE:
        train = _device_events(rng, TRAIN_ROWS, 0,
                               *_device_clean(rng, TRAIN_ROWS))
        util, mem, power, temp = _device_clean(rng, WINDOW_ROWS)
        if kind == "hw_contention":
            util[truth] = np.clip(rng.normal(98.0, 1.0, BURST_ROWS), 0, 100)
            power[truth] = rng.normal(280.0, 5.0, BURST_ROWS)
            temp[truth] = rng.normal(85.0, 1.5, BURST_ROWS)
        else:  # mem_leak: monotone climb well above the clean band
            mem[truth] = 6.0 + 0.5 * np.arange(BURST_ROWS)
        window = _device_events(rng, WINDOW_ROWS, TRAIN_ROWS,
                                util, mem, power, temp)
    elif layer == Layer.COLLECTIVE:
        slow = np.where(truth, 6.0, 1.0)
        train = _coll_events(rng, TRAIN_ROWS, 0, ones_train)
        window = _coll_events(rng, WINDOW_ROWS, TRAIN_ROWS, slow)
    else:
        slow = np.where(truth, 8.0, 1.0)
        train = _op_events(rng, TRAIN_ROWS, 0, ones_train)
        window = _op_events(rng, WINDOW_ROWS, TRAIN_ROWS, slow)
    return train, window, truth, layer


def fixture_suite(seed: int = 0) -> Dict[str, tuple]:
    """All cases: every fault kind plus the clean control."""
    return {kind: fixture_case(kind, seed=seed)
            for kind in ("clean", *FIXTURE_KINDS)}


def compute_golden(seed: int = 0, contamination: float = 0.05
                   ) -> Dict[str, object]:
    """Run every registered batch detector family over the fixture suite;
    returns the JSON-ready golden document (per-case truth + per-family
    flag masks)."""
    from repro.session import DetectorSpec
    from repro.session.registry import detector_backend, detector_names

    doc: Dict[str, object] = {
        "seed": seed,
        "contamination": contamination,
        "train_rows": TRAIN_ROWS,
        "window_rows": WINDOW_ROWS,
        "burst_rows": BURST_ROWS,
        "cases": {},
    }
    for kind, (train, window, truth, layer) in fixture_suite(seed).items():
        masks: Dict[str, List[int]] = {}
        for name in detector_names():
            try:
                cls = detector_backend(name, "batch")
            except KeyError:
                continue
            det = cls(DetectorSpec(backend=name, contamination=contamination,
                                   min_events=32, seed=seed))
            det.fit(train)
            res = det.update(window)
            if layer not in res:
                raise RuntimeError(
                    f"family {name!r} produced no {layer.value} detection "
                    f"for fixture {kind!r}")
            masks[name] = [int(f) for f in np.asarray(res[layer].flags)]
        doc["cases"][kind] = {
            "layer": layer.value,
            "truth": [int(t) for t in truth],
            "flags": masks,
        }
    return doc
