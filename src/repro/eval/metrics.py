"""Detection-quality metrics: per-layer event flags -> per-step scores.

The detectors flag *events*; chaos labels *steps*. The bridge is a per-layer
majority vote: a layer votes a step anomalous when at least ``vote`` of its
events at that step are flagged (always at least one event). A step is
predicted anomalous when any layer votes for it. The vote is what keeps the
false-alarm floor near the per-event contamination rate instead of its union
across every event at the step — see docs/evaluation.md#step-predictions.

All metrics are computed over the evaluation region ``[eval_start, n_steps)``
only: earlier steps are the detector's clean reference window (stream warmup
/ batch holdoff), where detection is not armed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.events import Layer
from repro.core.governor import policy_for


def step_predictions(detections: Dict[Layer, object], n_steps: int,
                     vote: float = 0.5) -> Dict[str, np.ndarray]:
    """Per-layer boolean step predictions (+ their union under "any").

    ``detections`` maps layers to DetectionResult / WindowDetection — both
    carry per-event ``flags`` and ``steps``. Events with unknown steps
    (step < 0) are ignored.
    """
    out: Dict[str, np.ndarray] = {"any": np.zeros(n_steps, dtype=bool)}
    for layer, det in detections.items():
        steps = np.asarray(det.steps)
        ok = (steps >= 0) & (steps < n_steps)
        steps = steps[ok].astype(np.int64)
        flags = np.asarray(det.flags)[ok]
        total = np.bincount(steps, minlength=n_steps)
        flagged = np.bincount(steps, weights=flags.astype(np.float64),
                              minlength=n_steps)
        need = np.maximum(np.ceil(total * vote), 1.0)
        pred = (total > 0) & (flagged >= need)
        out[layer.value] = pred
        out["any"] |= pred
    return out


def debounce(pred: np.ndarray, min_run: int = 2) -> np.ndarray:
    """Suppress predicted runs shorter than ``min_run`` consecutive steps.

    Injected faults are multi-step bursts; an isolated single-step flag is
    almost always a calibration false positive (probability ~p per layer per
    step), and requiring persistence drops the false-alarm floor from ~p to
    ~p^min_run while costing at most ``min_run - 1`` steps of detection lag.
    """
    if min_run <= 1 or not pred.any():
        return pred
    pred = np.asarray(pred, dtype=bool)
    out = np.zeros_like(pred)
    edges = np.flatnonzero(np.diff(np.concatenate(([0], pred.view(np.int8),
                                                   [0]))))
    for lo, hi in zip(edges[::2], edges[1::2]):
        if hi - lo >= min_run:
            out[lo:hi] = True
    return out


def first_flag_ts(detections: Dict[Layer, object]) -> Optional[float]:
    """Earliest flagged-event timestamp across layers (None without ts)."""
    firsts = []
    for det in detections.values():
        ts = getattr(det, "ts", None)
        flags = np.asarray(det.flags)
        if ts is not None and flags.any():
            firsts.append(float(np.asarray(ts)[flags].min()))
    return min(firsts) if firsts else None


@dataclasses.dataclass
class DetectionMetrics:
    """One scenario run's scores against the chaos labels."""

    precision: float
    recall: float
    f1: float
    false_alarm_rate: float  # flagged fraction of the clean eval steps
    ttd_steps: Optional[float]  # mean steps from fault start to first hit
    ttd_s: Optional[float]  # same in seconds (needs step timestamps)
    faults_total: int
    faults_detected: int
    eval_steps: int  # steps scored (eval region size)
    anomalous_steps: int  # labelled-anomalous steps in the eval region

    @property
    def fault_recall(self) -> float:
        """Window-level recall: detected fault windows / all windows."""
        return (self.faults_detected / self.faults_total
                if self.faults_total else 1.0)

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["fault_recall"] = self.fault_recall
        return d


def detection_metrics(pred: np.ndarray, labels: np.ndarray,
                      windows: Sequence[Tuple[int, int]],
                      eval_start: int = 0,
                      grace_steps: int = 0,
                      step_ts: Optional[np.ndarray] = None
                      ) -> DetectionMetrics:
    """Score per-step predictions against per-step labels + fault windows.

    * precision / recall / F1: step-level, over ``[eval_start, n)``.
    * false-alarm rate: predicted fraction of the *clean* steps in the eval
      region — for a clean-control run (no faults) this is the headline
      number, and the one CI holds below the documented ceiling.
    * time-to-detect: per merged fault window ``[lo, hi)``, the first
      predicted step in ``[lo, hi + grace_steps)``; TTD = that step - lo,
      averaged over detected windows. ``grace_steps`` covers detection
      cadence lag (a stream flush interval). With ``step_ts`` (per-step
      wall timestamps) the same quantity is also reported in seconds.
    """
    pred = np.asarray(pred, dtype=bool)
    labels = np.asarray(labels, dtype=bool)
    n = len(labels)
    region = np.zeros(n, dtype=bool)
    region[eval_start:] = True
    p, y = pred[region], labels[region]
    tp = int((p & y).sum())
    fp = int((p & ~y).sum())
    fn = int((~p & y).sum())
    precision = tp / (tp + fp) if tp + fp else 1.0
    recall = tp / (tp + fn) if tp + fn else 1.0
    f1 = (2 * precision * recall / (precision + recall)
          if precision + recall else 0.0)
    clean = int((~y).sum())
    far = fp / clean if clean else 0.0

    ttds: List[int] = []
    ttds_s: List[float] = []
    detected = 0
    windows = sorted(w for w in windows if w[0] >= eval_start)
    for i, (lo, hi) in enumerate(windows):
        # grace never reaches into the NEXT window: detecting fault i+1
        # must not credit fault i
        cap = min(hi + grace_steps, n,
                  windows[i + 1][0] if i + 1 < len(windows) else n)
        hits = np.flatnonzero(pred[lo:cap])
        if len(hits) == 0:
            continue
        detected += 1
        ttds.append(int(hits[0]))
        if step_ts is not None:
            first = lo + int(hits[0])
            if first < len(step_ts) and lo < len(step_ts):
                ttds_s.append(float(step_ts[first] - step_ts[lo]))
    return DetectionMetrics(
        precision=float(precision), recall=float(recall), f1=float(f1),
        false_alarm_rate=float(far),
        ttd_steps=float(np.mean(ttds)) if ttds else None,
        ttd_s=float(np.mean(ttds_s)) if ttds_s else None,
        faults_total=len(windows), faults_detected=detected,
        eval_steps=int(region.sum()), anomalous_steps=int(y.sum()))


# ---------------------------------------------------------------------------
# SLO-breach scoring (request-plane incidents vs serve fault windows)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SLOBreachMetrics:
    """SLO-breach incidents scored against serve-path fault windows.

    Only incidents stamped ``kind == "slo_breach"`` count — the request
    plane is thresholded, not density-modelled, so its quality question is
    different from detection: did each serve fault window raise a breach
    incident (recall), and did the *clean* control raise none
    (``incidents_total == 0`` when ``windows_total == 0``)?
    """

    incidents_total: int
    windows_total: int
    windows_detected: int
    spurious: int  # breach incidents overlapping no fault window

    @property
    def recall(self) -> float:
        return (self.windows_detected / self.windows_total
                if self.windows_total else 1.0)

    @property
    def clean(self) -> bool:
        """True when a fault-free run stayed breach-free (vacuously True
        for faulted runs — their score is recall/spurious)."""
        return self.windows_total > 0 or self.incidents_total == 0

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d.update(recall=self.recall, clean=self.clean)
        return d


def slo_breach_metrics(incidents: Sequence, windows: Sequence[Tuple[int, int]],
                       grace_steps: int = 0) -> SLOBreachMetrics:
    """Score a report's SLO-breach incidents against fault step windows.

    Breach rows lag their cause — a flooded request breaches when it
    *finishes*, which can be a full queue-drain after the burst window ends
    — so serve scoring uses a larger ``grace_steps`` than detection scoring.
    """
    from repro.stream.incidents import match_incidents

    breaches = [i for i in incidents
                if getattr(i, "kind", "anomaly") == "slo_breach"]
    m = match_incidents(breaches, windows, grace_steps=grace_steps)
    return SLOBreachMetrics(
        incidents_total=len(breaches), windows_total=len(windows),
        windows_detected=m.windows_detected, spurious=len(m.spurious))


# ---------------------------------------------------------------------------
# diagnosis scoring (blamed kind / nodes / action vs the injected labels)
# ---------------------------------------------------------------------------

def window_kinds(faults: Sequence) -> List[Tuple[Tuple[int, int], Set[str]]]:
    """Merged ``[lo, hi)`` fault windows with the set of injected kinds
    active in each — the ground truth a diagnosis is scored against.
    ``faults`` is a `Fault` sequence (``FaultInjector.faults``)."""
    spans = sorted(((f.start_step, f.end_step, f.kind) for f in faults))
    merged: List[Tuple[Tuple[int, int], Set[str]]] = []
    for lo, hi, kind in spans:
        if merged and lo <= merged[-1][0][1]:
            (mlo, mhi), kinds = merged[-1]
            merged[-1] = ((mlo, max(mhi, hi)), kinds | {kind})
        else:
            merged.append(((lo, hi), {kind}))
    return merged


@dataclasses.dataclass
class DiagnosisMetrics:
    """Diagnosis quality for one scenario run.

    Accuracies are over *emitted* diagnoses: a spurious diagnosis (no
    overlapping fault window) counts as wrong on every axis, and a faulted
    run that produced no diagnoses at all scores 0 (undetected is
    undiagnosed). A clean run with no diagnoses scores None (vacuous).
    """

    diagnoses_total: int
    matched: int  # diagnoses overlapping >= 1 fault window
    spurious: int
    kind_correct: int  # blamed kind in the overlapped windows' kinds
    node_correct: int  # blamed nodes intersect the faulted nodes
    action_correct: int  # recommended action matches the true kind's policy
    windows_total: int
    windows_diagnosed: int  # fault windows overlapped by >= 1 diagnosis

    def _rate(self, num: int) -> Optional[float]:
        if self.diagnoses_total:
            return num / self.diagnoses_total
        return None if self.windows_total == 0 else 0.0

    @property
    def kind_accuracy(self) -> Optional[float]:
        return self._rate(self.kind_correct)

    @property
    def node_accuracy(self) -> Optional[float]:
        return self._rate(self.node_correct)

    @property
    def action_match_rate(self) -> Optional[float]:
        return self._rate(self.action_correct)

    @property
    def coverage(self) -> Optional[float]:
        return (self.windows_diagnosed / self.windows_total
                if self.windows_total else None)

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d.update(kind_accuracy=self.kind_accuracy,
                 node_accuracy=self.node_accuracy,
                 action_match_rate=self.action_match_rate,
                 coverage=self.coverage)
        return d


def diagnosis_metrics(diagnoses: Sequence, faults: Sequence,
                      grace_steps: int = 0,
                      fault_nodes: Sequence[int] = (0,),
                      step_clock: Optional[Tuple[np.ndarray, np.ndarray]]
                      = None) -> DiagnosisMetrics:
    """Score `repro.diagnosis.Diagnosis` records against the injected
    schedule. A diagnosis matches window ``[lo, hi)`` when any of its steps
    lands in ``[lo, hi + grace_steps)`` (same overlap rule as
    `match_incidents`); its blamed kind is correct when it names any kind
    injected in a matched window, its action when it matches the policy of
    any such kind, its nodes when they intersect ``fault_nodes`` (the nodes
    the chaos schedule perturbed).

    ``step_clock`` is an optional ``(step_ids, ts)`` pair on the collector
    clock (e.g. the step layer's detection steps/ts): device-layer
    telemetry carries no step ids, so a device-only diagnosis has no steps
    of its own and is matched by mapping its ``[t_start, t_end]`` span onto
    the steps that ran concurrently."""
    windows = window_kinds(faults)
    fault_nodes = set(int(n) for n in fault_nodes)
    matched = spurious = kind_ok = node_ok = action_ok = 0
    hit_windows: Set[int] = set()
    for d in diagnoses:
        steps = set(d.steps)
        if not steps and step_clock is not None:
            ids, ts = step_clock
            span = (ts >= d.t_start) & (ts <= d.t_end)
            steps = set(int(x) for x in np.asarray(ids)[span])
        true_kinds: Set[str] = set()
        for w, ((lo, hi), kinds) in enumerate(windows):
            if any(lo <= s < hi + grace_steps for s in steps):
                true_kinds |= kinds
                hit_windows.add(w)
        if not true_kinds:
            spurious += 1
            continue
        matched += 1
        if d.fault_kind in true_kinds:
            kind_ok += 1
        if fault_nodes & set(int(n) for n in d.blamed_nodes):
            node_ok += 1
        if d.action.kind in {policy_for(k).action for k in true_kinds}:
            action_ok += 1
    return DiagnosisMetrics(
        diagnoses_total=len(diagnoses), matched=matched, spurious=spurious,
        kind_correct=kind_ok, node_correct=node_ok, action_correct=action_ok,
        windows_total=len(windows), windows_diagnosed=len(hit_windows))
