from repro.optim.optimizers import Optimizer, adamw, adafactor, make_optimizer  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_schedule, make_schedule  # noqa: F401
