"""Learning-rate schedules (warmup + cosine/linear decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return lr


def linear_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.0):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm,
                         peak_lr * (1 - (1 - final_frac) * t))

    return lr


def make_schedule(name: str, peak_lr: float, warmup: int, total: int):
    if name == "cosine":
        return cosine_schedule(peak_lr, warmup, total)
    if name == "linear":
        return linear_schedule(peak_lr, warmup, total)
    if name == "constant":
        return lambda step: jnp.asarray(peak_lr, jnp.float32)
    raise ValueError(f"unknown schedule {name}")
