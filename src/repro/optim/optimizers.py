"""Optimizers from scratch: AdamW (fp32 moments) and Adafactor (factored
second moments — the memory-viable choice for the >=100B assigned archs).

Optax-style minimal interface:
    opt = adamw(schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)   # apply: p + u
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "grad_norm": jnp.zeros((), jnp.float32),
                "lr": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr = lr_fn(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state["nu"], grads)

        def upd(p, m, v):
            mhat = m / b1c
            vhat = v / b2c
            u = mhat / (jnp.sqrt(vhat) + eps)
            wd = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            return (-lr * (u + wd)).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, {"step": step, "mu": mu, "nu": nu,
                         "grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def adafactor(lr_fn: Callable, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_rate: float = 0.8, weight_decay: float = 0.0,
              grad_clip: float = 1.0) -> Optimizer:
    """Shazeer & Stern 2018, no-momentum variant; matrices use factored
    (row, col) second moments -> O(n+m) optimizer memory per (n, m) matrix."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def moments(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(moments, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "grad_norm": jnp.zeros((), jnp.float32),
                "lr": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr = lr_fn(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay_rate)

        def upd(p, g, mom):
            g2 = g * g + eps
            if _factored(p):
                vr = beta2 * mom["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * mom["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]
                u = g / (jnp.sqrt(vr[..., None] / denom) * jnp.sqrt(vc[..., None, :]))
                new_mom = {"vr": vr, "vc": vc}
            else:
                v = beta2 * mom["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v)
                new_mom = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            wd = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            return (-lr * (u + wd)).astype(p.dtype), new_mom

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        outs = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "m": new_m, "grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn: Callable, weight_decay: float = 0.1,
                   grad_clip: float = 1.0) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "adafactor":
        return adafactor(lr_fn, weight_decay=weight_decay, grad_clip=grad_clip)
    raise ValueError(f"unknown optimizer {name}")
