"""Sharding-aware checkpointing with atomic writes, retention, async save,
auto-resume and ELASTIC restore (mesh shape may change between save/restore).

Layout:  <dir>/step_<n>/
            manifest.json        tree structure + shapes + dtypes + meta
            leaf_<i>.npy         one file per leaf (host-local full arrays)
         <dir>/step_<n>.tmp...   staging dir, renamed atomically on success

On restore, arrays are device_put against the *current* mesh's shardings —
a 16x16 checkpoint restores onto 2x16x16 (or 1 CPU device) unchanged, which
is the elastic-scaling path: save on N chips, resume on M.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_checkpoint(ckpt_dir: str, step: int, state, meta: Optional[Dict] = None,
                    keep: int = 3) -> str:
    """Atomic synchronous save. `state` is any pytree of arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "paths": _tree_paths(state),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(jax.device_get(l)).dtype) if hasattr(l, "dtype")
                   else "float32" for l in leaves],
        "meta": meta or {},
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i}.npy"),
                np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of `like`; if `shardings` (a matching pytree
    of jax.sharding.Sharding) is given, device_put each leaf against it —
    this is where elastic resharding happens."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves)} — structure changed between save and restore")
    loaded = [np.load(os.path.join(path, f"leaf_{i}.npy"))
              for i in range(len(leaves))]
    for arr, ref in zip(loaded, leaves):
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch: {arr.shape} vs {np.shape(ref)}")
    restored = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored, manifest["meta"]


class CheckpointManager:
    """Async (background-thread) checkpointing with auto-resume support.

    save() snapshots to host memory synchronously (cheap) and writes to disk
    in the background — training never blocks on the filesystem; wait() joins
    before exit or before the next save (bounded staleness of 1).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._executor = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, state, meta: Optional[Dict] = None) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._pending = self._executor.submit(
            save_checkpoint, self.ckpt_dir, step, host_state, meta, self.keep)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore_latest(self, like, shardings=None):
        """Returns (state, meta, step) or (None, None, None) when empty."""
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None, None
        state, meta = restore_checkpoint(self.ckpt_dir, step, like, shardings)
        return state, meta, step

    def close(self) -> None:
        self.wait()
        self._executor.shutdown(wait=True)
