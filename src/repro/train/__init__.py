from repro.train.step import TrainState, make_train_step, init_train_state  # noqa: F401
from repro.train.checkpoint import (CheckpointManager, save_checkpoint,  # noqa: F401
                                    restore_checkpoint, latest_step)
