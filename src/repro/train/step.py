"""Training step factory: loss + grad + optimizer, with microbatch gradient
accumulation, bf16 gradient all-reduce (compression), and fp32 master params.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models.model import Runtime, init_params, loss_fn
from repro.optim import Optimizer, make_optimizer, make_schedule


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(key, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_optimizer_for(cfg_t: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg_t.schedule, cfg_t.learning_rate,
                          cfg_t.warmup_steps, cfg_t.total_steps)
    return make_optimizer(cfg_t.optimizer, sched,
                          weight_decay=cfg_t.weight_decay,
                          grad_clip=cfg_t.grad_clip)


def make_train_step(cfg: ModelConfig, rt: Runtime, opt: Optimizer,
                    microbatches: int = 1,
                    grad_dtype: Any = jnp.bfloat16,
                    param_specs: Any = None) -> Callable:
    """Returns step(state, batch) -> (state, metrics). `batch` holds the
    GLOBAL batch; with microbatches>1 gradients are accumulated over a scan
    (activation memory / m, same math)."""

    def forward_loss(params, mb):
        loss, metrics = loss_fn(params, cfg, rt, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(forward_loss, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, metrics), grads = grad_fn(params, mb)
            # compress accumulation traffic: bf16 grads, fp32 accumulator
            acc = jax.tree.map(
                lambda a, g: a + g.astype(grad_dtype).astype(jnp.float32),
                acc, grads)
            return (acc, loss_sum + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        fwd_params = state.params
        if getattr(rt, "mixed_precision", False):
            # bf16 forward/backward weights + gradient traffic; fp32 master
            # params and optimizer states (grad all-reduce compression)
            fwd_params = jax.tree.map(
                lambda p: p.astype(rt.compute_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                state.params)
            if param_specs is not None and rt.mesh is not None:
                # pin the bf16 copies to the param shardings so GSPMD
                # all-gathers the CONVERTED tensors (bf16 wire bytes), not
                # the fp32 masters (no convert-sinking in this pipeline)
                from jax.sharding import NamedSharding
                fwd_params = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        p, NamedSharding(rt.mesh, s)),
                    fwd_params, param_specs)
        if microbatches > 1:
            loss, metrics, grads = accumulated(fwd_params, batch)
        else:
            loss, metrics, grads = single(fwd_params, batch)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        out = {"loss": loss, "grad_norm": opt_state.get("grad_norm", 0.0),
               "lr": opt_state.get("lr", 0.0), **metrics}
        return new_state, out

    return step
