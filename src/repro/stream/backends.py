"""Online per-window detection for the pluggable model families.

`OnlineModelDetector` is the family-generic counterpart of
`OnlineGMMDetector`: same aggregator-window lifecycle (idempotent
``warmup`` -> per-tick ``detect`` -> tracked model maintenance), same
featurisation (`_raw_features` / `_apply_baseline` from
`repro.stream.online`, which themselves delegate to `core.features` — the
batch and stream paths cannot drift), same `WindowDetection` output and
threshold policy. Only the per-layer model differs: any
`repro.detect.families.ScoreModel` (isolation ensemble, MAD envelope,
spectral residual) slots in via a factory.

Tracking, when enabled (``track``, from the spec's ``warm_start``):

* ``incremental=True``: ``partial_fit`` folds the window's inlier rows
  into the model (tree refresh / stat blend / covariance EMA — each
  family's warm refit);
* ``incremental=False``: a full ``fit`` on the inlier sample per sweep
  (the cold-refit-every-window regime, still cheap for these families);
* either way the threshold drifts toward the window's contamination
  quantile, clamped per sweep to a scale-free step (a fraction of the
  training scores' IQR — the families' score scales differ, so the GMM's
  fixed nat-step would be wrong for them).

`StreamMonitor` accepts any of these via its ``detector=`` parameter, so
the async snapshot/detect_snapshot/admit trio, incident engine, and wire
pipeline are inherited by every family for free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.events import Layer
from repro.core.features import name_medians
from repro.detect.families import ModelFactory, ScoreModel
from repro.stream.online import (OnlineGMMDetector, WindowDetection,
                                 WindowFeatures, _apply_baseline,
                                 _raw_features)
from repro.stream.window import FleetAggregator, LayerWindow


@dataclasses.dataclass
class _LayerModelState:
    medians: Dict[str, float]
    global_median: float
    mean: np.ndarray
    std: np.ndarray
    model: ScoreModel
    log_delta: float
    delta_step: float  # per-sweep threshold clamp (score-scale relative)
    refits: int = 0


class OnlineModelDetector:
    """One warm-startable ScoreModel per layer over the sliding windows."""

    # same exclusion as the GMM: REQUEST rows are SLO-thresholded
    LAYERS = OnlineGMMDetector.LAYERS

    def __init__(self, factory: ModelFactory, family: str = "",
                 contamination: float = 0.02, min_events: int = 64,
                 fit_rows: int = 2048, seed: int = 0,
                 delta_frac: float = 0.25):
        self.factory = factory
        self.family = family
        self.contamination = contamination
        self.min_events = min_events
        # cap on rows handed to fit/partial_fit per sweep (subsample; these
        # models need no fixed compiled shape, so no bootstrap-up)
        self.fit_rows = fit_rows
        # threshold clamp = delta_frac * IQR of the training scores: the
        # families' score scales differ by orders of magnitude, so the step
        # must be derived from the fitted score distribution
        self.delta_frac = float(delta_frac)
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        # knobs the session backend sets from the spec (GMM-parity surface;
        # drift_tol is accepted for uniformity — these families re-fit
        # continuously instead of watching a likelihood collapse)
        self.track = True
        self.incremental = True
        self.drift_tol = 3.0
        self.states: Dict[Layer, _LayerModelState] = {}

    # -- helpers --------------------------------------------------------------
    def _subsample(self, X: np.ndarray) -> np.ndarray:
        n = X.shape[0]
        if n <= self.fit_rows:
            return X
        return X[self._rng.choice(n, self.fit_rows, replace=False)]

    def _featurize(self, window: LayerWindow,
                   state: _LayerModelState) -> Optional[WindowFeatures]:
        if len(window) == 0:
            return None
        fs = _raw_features(window.layer, window.view())
        if fs is None:
            return None
        if window.layer != Layer.DEVICE:
            _apply_baseline(fs, state.medians, state.global_median)
        return fs

    def _cold_fit(self, layer: Layer,
                  fs: WindowFeatures) -> _LayerModelState:
        if layer == Layer.DEVICE:
            medians, gmed = {}, 0.0
        else:
            medians, gmed = name_medians(fs.names, fs.X[:, 0])
            _apply_baseline(fs, medians, gmed)
        mean = fs.X.mean(0)
        std = np.maximum(fs.X.std(0), 1e-9)
        Xs = (fs.X - mean) / std
        model = self.factory().fit(self._subsample(Xs))
        scores = model.decision_scores(Xs)
        q25, q75 = np.quantile(scores, (0.25, 0.75))
        return _LayerModelState(
            medians=medians, global_median=gmed, mean=mean, std=std,
            model=model,
            log_delta=float(np.quantile(scores, self.contamination)),
            delta_step=max(1e-3, self.delta_frac * float(q75 - q25)))

    # -- lifecycle ------------------------------------------------------------
    def warmup(self, agg: FleetAggregator) -> List[Layer]:
        """Fit baselines + models on the current (assumed-clean) windows of
        every layer not yet modelled; idempotent (late layers fit once they
        reach min_events). Returns the newly fitted layers."""
        fitted = []
        for layer in self.LAYERS:
            if layer in self.states:
                continue
            window = agg.window(layer)
            if len(window) < self.min_events:
                continue
            fs = _raw_features(layer, window.view())
            if fs is None or fs.X.shape[0] < self.min_events:
                continue
            self.states[layer] = self._cold_fit(layer, fs)
            fitted.append(layer)
        return fitted

    @property
    def warmed(self) -> bool:
        return bool(self.states)

    # -- per-window detection --------------------------------------------------
    def detect(self, agg: FleetAggregator, refit: bool = True
               ) -> Dict[Layer, WindowDetection]:
        out: Dict[Layer, WindowDetection] = {}
        for layer, state in self.states.items():
            fs = self._featurize(agg.window(layer), state)
            if fs is None or not len(fs.X):
                continue
            Xs = (fs.X - state.mean) / state.std
            scores = state.model.decision_scores(Xs)
            flags = scores < state.log_delta
            mode = "none"
            if refit and self.track:
                mode = self._track(state, Xs, flags, scores)
            out[layer] = WindowDetection(
                layer=layer, flags=flags, scores=scores,
                log_delta=state.log_delta, steps=fs.steps, nodes=fs.nodes,
                ts=fs.ts, refit=mode)
        return out

    def _track(self, state: _LayerModelState, Xs: np.ndarray,
               flags: np.ndarray, scores: np.ndarray) -> str:
        """Model maintenance after scoring: fold/refit on the inlier rows
        (flagged rows are censored — a burst must not teach the model) and
        drift the threshold toward the window's contamination quantile,
        clamped to ``delta_step`` per sweep."""
        inliers = Xs[~flags]
        if inliers.shape[0] < max(16, self.min_events // 4):
            return "none"
        sample = self._subsample(inliers)
        if self.incremental:
            state.model.partial_fit(sample)
        else:
            state.model.fit(sample)
        state.refits += 1
        target = float(np.quantile(scores, self.contamination))
        state.log_delta += float(np.clip(target - state.log_delta,
                                         -state.delta_step,
                                         state.delta_step))
        return "warm"

    def stats(self) -> Dict[str, object]:
        return {layer.value: dict(
                    {"family": self.family,
                     "log_delta": s.log_delta,
                     "warm_refits": s.refits,
                     "cold_refits": 0},
                    **(s.model.stats() if hasattr(s.model, "stats") else {}))
                for layer, s in self.states.items()}
