"""Online windowed GMM detection over aggregator windows.

`OnlineGMMDetector` is the streaming counterpart of `core.detector`'s
batch `FullStackMonitor`:

* features are computed **directly from the columnar windows** (vectorised;
  no `Event` objects), with the same per-layer feature spaces as
  `core.features.build_features`;
* per-name duration baselines and the standardiser are fitted once on the
  warmup window and then frozen (a detector must not re-derive its
  normalisation from the window it scores);
* each detection tick refits the GMM **warm-started from the previous
  window's params** via `fit_gmm_streaming(params0=...)` — a few EM
  iterations on the inlier rows track slow drift at a fraction of a cold
  fit's cost;
* a likelihood collapse on the *inlier* rows (beyond ``drift_tol`` nats)
  signals concept drift and triggers a full cold refit + threshold
  recalibration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.events import Layer
from repro.core.features import (COLLECTIVE_FEATURES, DEVICE_FEATURES,
                                 LATENCY_FEATURES, baseline_for,
                                 name_medians, raw_feature_matrix)
from repro.core.gmm import (GMMParams, SuffStats, fit_gmm_streaming,
                            fold_stats, params_from_stats, score_samples,
                            stats_from_batch, total_log_likelihood)
from repro.detect.cache import SHAPE_CACHE, pad_to_bucket
from repro.stream.window import FleetAggregator, LayerWindow


@dataclasses.dataclass
class WindowFeatures:
    """One layer window, featurised."""

    layer: Layer
    X: np.ndarray  # (N, D)
    steps: np.ndarray  # (N,) int64
    nodes: np.ndarray  # (N,) int32
    ts: np.ndarray  # (N,) float64
    names: np.ndarray  # (N,) source event names


@dataclasses.dataclass
class WindowDetection:
    """Per-layer flags for the current window (streaming DetectionResult)."""

    layer: Layer
    flags: np.ndarray  # (N,) bool
    scores: np.ndarray  # (N,) best-component log density
    log_delta: float
    steps: np.ndarray
    nodes: np.ndarray
    ts: np.ndarray
    refit: str = "warm"  # warm | cold (drift) | none

    @property
    def anomaly_rate(self) -> float:
        return float(np.mean(self.flags)) if len(self.flags) else 0.0

    def anomalous_steps(self) -> np.ndarray:
        return np.unique(self.steps[self.flags & (self.steps >= 0)])


@dataclasses.dataclass
class _LayerState:
    medians: Dict[str, float]
    global_median: float
    mean: np.ndarray
    std: np.ndarray
    params: GMMParams
    log_delta: float
    ll_fit: float  # mean total log-likelihood at fit time (drift reference)
    n_components: int
    cold_refits: int = 0
    warm_refits: int = 0
    # incremental-EM state: per-sample sufficient statistics of everything
    # folded so far, the newest event timestamp already folded, and an
    # effective sample count (capped, so old windows decay)
    stats: Optional[SuffStats] = None
    last_ts: float = float("-inf")
    n_seen: int = 0
    folds_since_anchor: int = 0
    last_n: int = 0  # window rows at the previous tracked sweep


def _raw_features(layer: Layer, v: Dict[str, np.ndarray]
                  ) -> Optional[WindowFeatures]:
    """Window columns -> unbaselined feature matrix (rel_dur column zeroed;
    the caller fills it from fitted per-name medians). The matrix itself
    comes from the SAME `core.features.raw_feature_matrix` the batch path
    uses — batch and stream cannot drift apart."""
    names = v["name"]
    keep = np.flatnonzero(
        ~np.char.startswith(names.astype(str, copy=False), "static/"))
    raw = raw_feature_matrix(layer, v, keep)
    if raw is None:
        return None
    X, keep = raw
    return WindowFeatures(layer=layer, X=X, steps=v["step"][keep],
                          nodes=v["node"][keep], ts=v["ts"][keep],
                          names=names[keep])


def _apply_baseline(fs: WindowFeatures, medians: Dict[str, float],
                    global_median: float) -> None:
    """Fill rel_dur (column 1) = log_dur - fitted per-name median."""
    fs.X[:, 1] = fs.X[:, 0] - baseline_for(fs.names, medians, global_median)


class OnlineGMMDetector:
    """One warm-started GMM per layer over the aggregator's sliding windows."""

    # REQUEST rows are SLO-thresholded by the serve plane, not GMM-modelled:
    # request latencies are workload-shaped (queue wait under load), so a
    # density fit over them would alarm on every traffic change.
    LAYERS = tuple(l for l in Layer if l is not Layer.REQUEST)

    def __init__(self, n_components: int = 3, contamination: float = 0.02,
                 refit_iters: int = 4, cold_iters: int = 40,
                 drift_tol: float = 3.0, min_events: int = 64,
                 reg: float = 1e-2, fit_rows: int = 2048, seed: int = 0,
                 delta_step: float = 2.0, incremental: bool = True):
        self.n_components = n_components
        self.contamination = contamination
        self.refit_iters = refit_iters
        self.cold_iters = cold_iters
        self.drift_tol = drift_tol
        self.min_events = min_events
        self.reg = reg
        # EM refits run on a fixed-size bootstrap of the window and scoring
        # pads to power-of-two buckets: a sliding window changes N every
        # tick, and XLA recompiles per shape — fixed/bucketed shapes turn
        # per-tick recompilation (~0.5 s) into a one-time cost.
        self.fit_rows = fit_rows
        # max nats the threshold may move per warm refit while tracking the
        # window's contamination quantile: enough to follow slow benign
        # drift (host timing, thermal), far too slow for a burst fault
        # (tens-hundreds of nats below delta) to drag the threshold down
        self.delta_step = float(delta_step)
        # incremental warm refits: fold ONLY the window rows newer than the
        # last fold into persistent sufficient statistics (one fused E-step
        # pass over the new rows + an O(K D^2) host M-step) instead of
        # running ``refit_iters`` EM iterations over a fit_rows bootstrap of
        # the whole window every tick
        self.incremental = bool(incremental)
        # effective-sample cap: keeps the fold weight rho bounded away from
        # zero so the model stays adaptive after long uptimes
        self.n_seen_cap = 8 * fit_rows
        # every anchor_every folds, re-anchor the statistics with one
        # bootstrap warm refit over the live window: stepwise folds forget
        # at rho-rate while the scoring window spans the full horizon, and
        # without an anchor the model slowly walks away from the very rows
        # it scores (the contamination quantile then ratchets the threshold
        # into the bulk, diluting incident deficits)
        self.anchor_every = 8
        # fold only while the model agrees with the window: a flag fraction
        # far above the contamination target means the fit is wrong (e.g. a
        # warmup sample too narrow for the live distribution), and folds
        # cannot repair it — flagged rows are censored from learning, so the
        # misfit locks in. Those sweeps take the bootstrap-refit branch
        # instead, which is how the pre-incremental detector adapted.
        self.anchor_flag_frac = max(4.0 * contamination, 0.05)
        # stepwise EM assumes a (quasi-)stationary sample stream; while the
        # window is still ramping up — growing more than this fraction per
        # sweep — its distribution is still filling in, and folds can only
        # chase it. Ramp-up sweeps take the bootstrap branch (the model
        # continuously re-tracks the growing window, as the pre-incremental
        # detector did); folds start once the window reaches steady state,
        # which is where the kernel-cost win matters anyway
        self.fold_growth_tol = 0.05
        self.seed = seed
        # model tracking switch: False freezes every layer model after its
        # warmup fit (no warm refits, no drift-triggered cold refits)
        self.track = True
        self.states: Dict[Layer, _LayerState] = {}
        self._key = jax.random.PRNGKey(seed)
        self._rng = np.random.default_rng(seed)

    # -- helpers --------------------------------------------------------------
    def _split_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _fit_sample(self, X: np.ndarray) -> np.ndarray:
        """Exactly fit_rows rows: bootstrap up when short, subsample down
        when long. EM sees one compiled shape for the detector's lifetime."""
        n = X.shape[0]
        if n == self.fit_rows:
            return X
        idx = self._rng.choice(n, self.fit_rows, replace=n < self.fit_rows)
        return X[idx]

    @staticmethod
    def _score_bucketed(Xs: np.ndarray, params: GMMParams) -> np.ndarray:
        """score_samples with N padded to the next power of two (>=256):
        scores of the zero padding rows are computed and discarded."""
        Xp, n = pad_to_bucket(np.ascontiguousarray(Xs, dtype=np.float32))
        SHAPE_CACHE.record("score", Xp.shape[0], Xp.shape[1],
                           params.n_components)
        return np.asarray(score_samples(Xp, params)[0])[:n]

    def _featurize(self, window: LayerWindow,
                   state: _LayerState) -> Optional[WindowFeatures]:
        if len(window) == 0:
            return None
        fs = _raw_features(window.layer, window.view())
        if fs is None:
            return None
        if window.layer != Layer.DEVICE:
            _apply_baseline(fs, state.medians, state.global_median)
        return fs

    def _cold_fit(self, layer: Layer, fs: WindowFeatures) -> _LayerState:
        if layer == Layer.DEVICE:
            medians, gmed = {}, 0.0
        else:
            medians, gmed = name_medians(fs.names, fs.X[:, 0])
            _apply_baseline(fs, medians, gmed)
        mean = fs.X.mean(0)
        std = np.maximum(fs.X.std(0), 1e-9)
        Xs = ((fs.X - mean) / std).astype(np.float32)
        k = min(self.n_components, max(1, Xs.shape[0] // 32))
        sample = self._fit_sample(Xs)
        params, lls = fit_gmm_streaming(sample,
                                        self._split_key(), n_components=k,
                                        n_iters=self.cold_iters, reg=self.reg)
        scores = self._score_bucketed(Xs, params)
        log_delta = float(np.quantile(scores, self.contamination))
        state = _LayerState(medians=medians, global_median=gmed, mean=mean,
                            std=std, params=params, log_delta=log_delta,
                            ll_fit=float(lls[-1]), n_components=k)
        self._seed_stats(state, sample, float(fs.ts.max()) if len(fs.ts)
                         else float("-inf"))
        return state

    def _seed_stats(self, state: _LayerState, sample: np.ndarray,
                    last_ts: float) -> None:
        """(Re)initialise the incremental-EM statistics from the sample a
        cold fit just converged on, under the fitted params."""
        if not self.incremental:
            return
        state.stats, _ = stats_from_batch(sample, state.params)
        state.n_seen = sample.shape[0]
        state.last_ts = last_ts
        state.folds_since_anchor = 0

    # -- lifecycle ------------------------------------------------------------
    def warmup(self, agg: FleetAggregator) -> List[Layer]:
        """Fit baselines + cold GMMs on the current (assumed-clean) windows
        of every layer not yet modelled. Idempotent: call again on later
        ticks so slow layers (device telemetry trickles in at its polling
        interval) get fitted once they reach min_events instead of staying
        unmonitored forever. Returns the newly fitted layers."""
        fitted = []
        for layer in self.LAYERS:
            if layer in self.states:
                continue
            window = agg.window(layer)
            if len(window) < self.min_events:
                continue
            fs = _raw_features(layer, window.view())
            if fs is None or fs.X.shape[0] < self.min_events:
                continue
            self.states[layer] = self._cold_fit(layer, fs)
            fitted.append(layer)
        return fitted

    @property
    def warmed(self) -> bool:
        return bool(self.states)

    # -- per-window detection --------------------------------------------------
    def detect(self, agg: FleetAggregator, refit: bool = True
               ) -> Dict[Layer, WindowDetection]:
        """Score every fitted layer's current window; then (optionally) track
        the model: warm EM refit on the inlier rows, cold refit on drift."""
        out: Dict[Layer, WindowDetection] = {}
        for layer, state in self.states.items():
            fs = self._featurize(agg.window(layer), state)
            if fs is None or not len(fs.X):
                continue
            Xs = ((fs.X - state.mean) / state.std).astype(np.float32)
            scores = self._score_bucketed(Xs, state.params)
            flags = scores < state.log_delta
            mode = "none"
            if refit and self.track:
                mode = self._track(layer, state, Xs, flags, scores, fs.ts)
            out[layer] = WindowDetection(
                layer=layer, flags=flags, scores=scores,
                log_delta=state.log_delta, steps=fs.steps, nodes=fs.nodes,
                ts=fs.ts, refit=mode)
        return out

    def _track(self, layer: Layer, state: _LayerState, Xs: np.ndarray,
               flags: np.ndarray, scores: np.ndarray,
               ts: np.ndarray) -> str:
        """Model maintenance after scoring: warm refit on inliers; full
        refit + threshold recalibration when the inlier likelihood collapses
        (concept drift, not a transient anomaly burst). Warm refits also
        nudge the threshold toward the window's contamination quantile
        (clamped to ``delta_step`` nats per refit) so slow benign drift
        cannot accumulate flags window after window."""
        inliers = Xs[~flags]
        if inliers.shape[0] < max(8 * state.n_components, 16):
            return "none"
        sample = self._fit_sample(inliers)
        ll_now = float(total_log_likelihood(sample, state.params))
        if ll_now < state.ll_fit - self.drift_tol:
            params, lls = fit_gmm_streaming(
                sample, self._split_key(), n_components=state.n_components,
                n_iters=self.cold_iters, reg=self.reg)
            rescored = self._score_bucketed(sample, params)
            state.params = params
            state.log_delta = float(np.quantile(rescored, self.contamination))
            state.ll_fit = float(lls[-1])
            state.cold_refits += 1
            self._seed_stats(state, sample,
                             float(ts.max()) if len(ts) else state.last_ts)
            return "cold"
        flag_frac = float(np.count_nonzero(flags)) / max(1, flags.shape[0])
        n_now = int(Xs.shape[0])
        steady = (n_now - state.last_n) <= self.fold_growth_tol * n_now
        state.last_n = n_now
        if (self.incremental and state.stats is not None and steady
                and state.folds_since_anchor < self.anchor_every
                and flag_frac <= self.anchor_flag_frac):
            mode = self._fold_new(state, Xs, flags, ts)
        else:
            params, lls = fit_gmm_streaming(
                sample, self._split_key(), n_components=state.n_components,
                n_iters=self.refit_iters, reg=self.reg, params0=state.params)
            state.params = params
            state.ll_fit = float(lls[-1])
            state.warm_refits += 1
            self._seed_stats(state, sample,
                             float(ts.max()) if len(ts) else state.last_ts)
            mode = "warm"
        # threshold tracking: move delta toward the contamination quantile
        # of ALL scored rows (never inliers-only — censoring the tail and
        # re-quantiling it ratchets the threshold into the bulk). The
        # clamped step follows slow drift but is negligible against the
        # tens-to-hundreds of nats a genuine burst sits below delta.
        target = float(np.quantile(scores, self.contamination))
        state.log_delta += float(np.clip(target - state.log_delta,
                                         -self.delta_step, self.delta_step))
        return mode

    def _fold_new(self, state: _LayerState, Xs: np.ndarray,
                  flags: np.ndarray, ts: np.ndarray) -> str:
        """Incremental warm refit (stepwise EM): one fused E-step pass over
        the inlier rows NEWER than the last fold, convex-folded into the
        persistent per-sample statistics, then a tiny host-side M-step.

        Against the bootstrap warm refit this replaces, the kernel work per
        tick drops from ``refit_iters`` passes over fit_rows rows to one
        pass over only the rows that arrived since the previous tick — and
        the rows are padded to a power-of-two bucket so the pass reuses a
        compiled executable (see repro.detect.cache)."""
        new = (~flags) & (ts > state.last_ts)
        n_new = int(np.count_nonzero(new))
        if n_new < max(2 * state.n_components, 4):
            return "warm"  # nothing fresh to learn from; threshold still tracks
        Xp, _ = pad_to_bucket(np.ascontiguousarray(Xs[new], dtype=np.float32))
        SHAPE_CACHE.record("em-stats", Xp.shape[0], Xp.shape[1],
                           state.n_components)
        batch, ll_new = stats_from_batch(Xp, state.params, nvalid=n_new)
        # fold weight matched to the batch's share of the LIVE window (not
        # just of history): the model approximates the window average it
        # scores against, instead of exponentially forgetting rows the
        # window still holds
        rho = min(0.5, n_new / max(1, Xs.shape[0], state.n_seen + n_new))
        state.stats = fold_stats(state.stats, batch, rho)
        state.params = params_from_stats(state.stats, self.reg)
        # drift reference tracks the same convex combination as the stats:
        # a genuine likelihood collapse still opens a >drift_tol gap because
        # rho is bounded by the window/history ratio
        state.ll_fit = (1.0 - rho) * state.ll_fit + rho * ll_new
        state.n_seen = min(state.n_seen + n_new, self.n_seen_cap)
        state.last_ts = float(ts.max())
        state.folds_since_anchor += 1
        state.warm_refits += 1
        return "warm"

    def stats(self) -> Dict[str, object]:
        return {layer.value: {"k": s.n_components,
                              "log_delta": s.log_delta,
                              "ll_fit": s.ll_fit,
                              "warm_refits": s.warm_refits,
                              "cold_refits": s.cold_refits,
                              "n_seen": s.n_seen}
                for layer, s in self.states.items()}
