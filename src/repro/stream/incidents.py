"""Incident engine: raw per-layer flags -> ranked cross-node incidents.

A production fleet monitor cannot page an operator per flagged event — a
single faulty NIC produces thousands of collective-layer flags across every
node in the ring. The engine turns window detections into a small number of
`Incident` records by

1. pooling flagged rows from all layers/nodes,
2. clustering them in time (flags separated by less than ``gap_s`` belong to
   the same incident),
3. attributing each cluster: the **suspect layer** is the non-symptom layer
   with the largest total score deficit (the STEP layer flags for *every*
   fault — it is the symptom, not the cause), the **suspect nodes** are the
   nodes carrying the bulk of that layer's deficit,
4. ranking by severity (total deficit, i.e. how far below delta the density
   fell, summed over flags).

Clusters are held open while new flags keep arriving and finalised once the
stream has moved ``close_after_s`` past their last flag.

The engine accepts batch `DetectionResult`s alongside streaming
`WindowDetection`s (the session's batch finalise runs its final sweep
through a fresh engine), and finalised incidents feed the root-cause
diagnoser (`repro.diagnosis`) — ``layer_first_ts`` is recorded per incident
so the diagnoser can order the causal chain by deficit lead/lag.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.events import Layer
from repro.stream.online import WindowDetection

# layers that aggregate the whole stack: never blamed while a specific layer
# also carries deficit
SYMPTOM_LAYERS = (Layer.STEP,)


@dataclasses.dataclass
class Incident:
    incident_id: int
    t_start: float
    t_end: float
    suspect_layer: Layer
    suspect_nodes: List[int]
    severity: float  # total score deficit across flags
    n_flags: int
    steps: List[int]  # anomalous step ids (union over layers)
    layer_deficit: Dict[str, float]  # layer -> summed (delta - score)
    node_flags: Dict[int, int]  # node -> flag count
    status: str = "open"  # open | closed
    # layer -> earliest flagged-event ts in this incident. The diagnosis
    # engine reads this as the causal lead/lag ordering: the layer that
    # flagged first leads the chain (see repro.diagnosis).
    layer_first_ts: Dict[str, float] = dataclasses.field(default_factory=dict)
    # "anomaly" (GMM density flags) or "slo_breach" (request-plane SLO
    # thresholding, see repro.serve.slo) — the two planes cluster through
    # the same engine but are reported and diagnosed separately
    kind: str = "anomaly"

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["suspect_layer"] = self.suspect_layer.value
        return d

    def render(self) -> str:
        nodes = ",".join(str(n) for n in self.suspect_nodes)
        steps = _fmt_steps(self.steps)
        layers = " ".join(f"{k}={v:.1f}" for k, v in sorted(
            self.layer_deficit.items(), key=lambda kv: -kv[1]))
        tag = "" if self.kind == "anomaly" else f" {self.kind}"
        return (f"[incident #{self.incident_id} {self.status}{tag}] "
                f"t={self.t_start:.2f}s..{self.t_end:.2f}s "
                f"suspect={self.suspect_layer.value} node(s)={nodes} "
                f"severity={self.severity:.1f} flags={self.n_flags} "
                f"steps={steps}\n    layer deficit: {layers}")


def _fmt_steps(steps: Sequence[int]) -> str:
    if not steps:
        return "-"
    s = sorted(steps)
    if len(s) > 8:
        return f"{s[0]}..{s[-1]} ({len(s)} steps)"
    return ",".join(str(x) for x in s)


class IncidentEngine:
    """Stateful flag clustering across detection ticks."""

    def __init__(self, gap_s: float = 1.0, close_after_s: float = 2.0,
                 min_flags: int = 8, deficit_cap: float = 1e3):
        self.gap_s = float(gap_s)
        self.close_after_s = float(close_after_s)
        self.min_flags = int(min_flags)
        # per-flag deficit cap: a near-constant feature (std floored at 1e-9
        # in the standardizer) can push a single flag's (delta - score) to
        # ~1e12, which would let one degenerate feature dominate cross-layer
        # attribution and severity ranking
        self.deficit_cap = float(deficit_cap)
        self.incidents: List[Incident] = []  # finalised, ranked on report
        self._next_id = 1
        # pending flag rows: (ts, layer_idx, node, step, deficit)
        self._pending: List[np.ndarray] = []
        self._layers = tuple(Layer)
        self._layer_idx = {l: i for i, l in enumerate(self._layers)}
        # sliding windows re-score the same event every tick; the watermark
        # admits each (layer, node) row into the incident stream exactly once
        self._watermark: Dict[tuple, float] = {}
        self._floor = -np.inf  # rows at or before this ts never enter
        self._layer_floor: Dict[int, float] = {}  # per-layer late-fit floors

    @property
    def n_pending_flags(self) -> int:
        """Flag rows admitted but not yet clustered into a finalised
        incident — the backlog an open incident is accumulating."""
        return int(sum(a.shape[0] for a in self._pending))

    # -- ingestion ------------------------------------------------------------
    def set_floor(self, ts: float) -> None:
        """Exclude everything at or before ``ts`` from incident formation —
        called after warmup so the reference window's own calibration false
        positives (the contamination quantile flags ~c% of it by
        construction) don't open a spurious incident."""
        self._floor = float(ts)

    def set_layer_floor(self, layer: Layer, ts: float) -> None:
        """Same exclusion, for one layer — used when a layer is fitted late
        (its training window would otherwise feed calibration flags straight
        into an incident)."""
        self._layer_floor[self._layer_idx[layer]] = float(ts)

    def set_node_floor(self, layer: Layer, node: int, ts: float) -> None:
        """Same exclusion, for one (layer, node) pair — used by the
        hierarchical plane when one GROUP warms a layer late: only that
        group's member nodes should have their calibration flags excluded,
        not the whole fleet's."""
        key = (self._layer_idx[layer], int(node))
        self._watermark[key] = max(
            self._watermark.get(key, -np.inf), float(ts))

    def update(self, detections: Dict[Layer, WindowDetection],
               now: Optional[float] = None) -> List[Incident]:
        """Feed one tick's detections; returns incidents finalised by this
        update (clusters whose last flag is > close_after_s old)."""
        return self._finalise(self.ingest(detections, now))

    def finalise(self, now: float) -> List[Incident]:
        """Close clusters whose last flag is > close_after_s before ``now``
        (public wrapper; pair with `ingest`)."""
        return self._finalise(float(now))

    def ingest(self, detections: Dict[Layer, WindowDetection],
               now: Optional[float] = None) -> float:
        """Admit one tick's detections into the pending flag stream WITHOUT
        finalising. The hierarchical plane admits every group's detections
        first and then calls `finalise` once, so a cross-group flag cluster
        can never be split by group feed order. Returns the newest timestamp
        observed (input ``now`` folded in)."""
        rows = []
        t_max = now if now is not None else 0.0
        for layer, det in detections.items():
            # batch DetectionResults are accepted alongside streaming
            # WindowDetections: ts may be absent (legacy feature paths) and
            # nodes default to a single-node fleet
            ts_col = getattr(det, "ts", None)
            if ts_col is None:
                continue
            nodes_col = getattr(det, "nodes", None)
            if nodes_col is None:
                nodes_col = np.zeros(len(ts_col), dtype=np.int32)
            if len(ts_col):
                t_max = max(t_max, float(ts_col.max()))
            fresh = np.zeros(len(ts_col), dtype=bool)
            li = self._layer_idx[layer]
            floor = max(self._floor, self._layer_floor.get(li, -np.inf))
            for node in np.unique(nodes_col):
                key = (li, int(node))
                on_node = nodes_col == node
                node_ts = ts_col[on_node]
                wm = self._watermark.get(key, floor)
                fresh[on_node] = node_ts > wm
                self._watermark[key] = max(wm, float(node_ts.max()))
            f = det.flags & fresh
            if not f.any():
                continue
            deficit = np.clip(det.log_delta - det.scores[f], 0.0,
                              self.deficit_cap)
            rows.append(np.stack([
                ts_col[f],
                np.full(f.sum(), self._layer_idx[layer], dtype=np.float64),
                nodes_col[f].astype(np.float64),
                det.steps[f].astype(np.float64),
                deficit,
            ], axis=1))
        if rows:
            self._pending.append(np.concatenate(rows, axis=0))
        return t_max

    def flush(self) -> List[Incident]:
        """Force-finalise everything pending (end of run)."""
        return self._finalise(float("inf"))

    # -- clustering -----------------------------------------------------------
    def _finalise(self, now: float) -> List[Incident]:
        if not self._pending:
            return []
        rows = np.concatenate(self._pending, axis=0)
        rows = rows[np.argsort(rows[:, 0], kind="stable")]
        ts = rows[:, 0]
        # split where the inter-flag gap exceeds gap_s
        cuts = np.flatnonzero(np.diff(ts) > self.gap_s) + 1
        groups = np.split(rows, cuts)
        closed: List[Incident] = []
        keep: List[np.ndarray] = []
        for g in groups:
            if now - g[-1, 0] <= self.close_after_s:
                keep.append(g)  # still hot: may extend next tick
                continue
            inc = self._attribute(g)
            if inc is not None:
                closed.append(inc)
        self._pending = keep
        self.incidents.extend(closed)
        return closed

    def _attribute(self, g: np.ndarray) -> Optional[Incident]:
        if g.shape[0] < self.min_flags:
            return None
        layer_ids = g[:, 1].astype(int)
        deficits = g[:, 4]
        layer_deficit: Dict[str, float] = {}
        layer_first_ts: Dict[str, float] = {}
        for li in np.unique(layer_ids):
            on = layer_ids == li
            layer_deficit[self._layers[li].value] = float(deficits[on].sum())
            layer_first_ts[self._layers[li].value] = float(g[on, 0].min())
        # suspect layer: largest deficit among cause layers; symptom layers
        # only when nothing specific flagged
        cause = {k: v for k, v in layer_deficit.items()
                 if Layer(k) not in SYMPTOM_LAYERS}
        pool = cause or layer_deficit
        suspect_layer = Layer(max(pool, key=pool.get))
        # suspect nodes: nodes carrying >= 50% of the top node's deficit on
        # the suspect layer
        on_layer = layer_ids == self._layer_idx[suspect_layer]
        node_def: Dict[int, float] = {}
        for node in np.unique(g[on_layer, 2].astype(int)):
            node_def[int(node)] = float(
                deficits[on_layer & (g[:, 2] == node)].sum())
        top = max(node_def.values())
        suspects = sorted(n for n, d in node_def.items() if d >= 0.5 * top)
        node_flags = {int(n): int((g[:, 2] == n).sum())
                      for n in np.unique(g[:, 2].astype(int))}
        steps = np.unique(g[:, 3].astype(int))
        inc = Incident(
            incident_id=self._next_id,
            t_start=float(g[0, 0]), t_end=float(g[-1, 0]),
            suspect_layer=suspect_layer, suspect_nodes=suspects,
            severity=float(deficits.sum()), n_flags=int(g.shape[0]),
            steps=[int(s) for s in steps if s >= 0],
            layer_deficit=layer_deficit, node_flags=node_flags,
            status="closed", layer_first_ts=layer_first_ts)
        self._next_id += 1
        return inc

    # -- reporting ------------------------------------------------------------
    def ranked(self) -> List[Incident]:
        return sorted(self.incidents, key=lambda i: -i.severity)

    def render_report(self) -> str:
        incs = self.ranked()
        if not incs:
            return "no incidents"
        lines = [f"{len(incs)} incident(s), ranked by severity:"]
        lines += [i.render() for i in incs]
        return "\n".join(lines)

    def json_report(self) -> str:
        return json.dumps([i.to_json() for i in self.ranked()], indent=1)


# ---------------------------------------------------------------------------
# incident <-> ground-truth matching (evaluation harness)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IncidentMatch:
    """Incidents scored against labelled fault windows (chaos ground truth).

    ``window_hits[i]`` lists the incident ids overlapping fault window ``i``;
    an incident overlapping no window is spurious. Precision/recall are at
    the incident/window level — the step-level metrics live in
    `repro.eval.metrics`.
    """

    window_hits: List[List[int]]
    spurious: List[int]  # incident ids matching no fault window

    @property
    def windows_detected(self) -> int:
        return sum(1 for hits in self.window_hits if hits)

    @property
    def recall(self) -> float:
        return (self.windows_detected / len(self.window_hits)
                if self.window_hits else 1.0)

    @property
    def precision(self) -> float:
        n_inc = len(self.spurious) + len(
            {i for hits in self.window_hits for i in hits})
        return 1.0 - len(self.spurious) / n_inc if n_inc else 1.0

    def to_json(self) -> Dict[str, object]:
        return {"window_hits": self.window_hits, "spurious": self.spurious,
                "windows_detected": self.windows_detected,
                "recall": self.recall, "precision": self.precision}


def match_incidents(incidents: Sequence[Incident],
                    windows: Sequence[tuple],
                    grace_steps: int = 0) -> IncidentMatch:
    """Match incidents to ``[start, end)`` fault step windows by step overlap.

    ``windows`` is typically ``FaultInjector.windows()``. An incident counts
    toward window ``[lo, hi)`` when any of its anomalous steps lands in
    ``[lo, hi + grace_steps)`` — detection can lag the window by up to a
    flush interval, which is what the grace covers.
    """
    window_hits: List[List[int]] = [[] for _ in windows]
    spurious: List[int] = []
    for inc in incidents:
        steps = set(inc.steps)
        hit = False
        for w, (lo, hi) in enumerate(windows):
            if any(lo <= s < hi + grace_steps for s in steps):
                window_hits[w].append(inc.incident_id)
                hit = True
        if not hit:
            spurious.append(inc.incident_id)
    return IncidentMatch(window_hits=window_hits, spurious=spurious)
