"""Streaming fleet monitor: online windowed detection, multi-node
aggregation, and incident reports on top of the eACGM collector/probe stack.

Public API:
    StreamMonitor     — end-to-end orchestrator (agents -> windows ->
                        online GMM -> incidents)
    NodeAgent         — per-node ring-buffer flusher (wire producer)
    FleetAggregator   — multi-node columnar sliding windows
    OnlineGMMDetector — warm-started per-window EM + drift refit
    IncidentEngine    — flag clustering / attribution / ranking
    match_incidents   — incidents scored against labelled fault windows
    wire              — columnar Event-batch serialization
"""
from repro.stream import wire  # noqa: F401
from repro.stream.agent import NodeAgent  # noqa: F401
from repro.stream.incidents import (Incident, IncidentEngine,  # noqa: F401
                                    IncidentMatch, match_incidents)
from repro.stream.monitor import StreamMonitor  # noqa: F401
from repro.stream.online import OnlineGMMDetector, WindowDetection  # noqa: F401
from repro.stream.window import FleetAggregator, LayerWindow  # noqa: F401
