"""Per-layer sliding windows over preallocated numpy columns + the fleet
aggregator that feeds them from node batches.

The aggregator is the service-side state of the streaming monitor: one
`LayerWindow` per monitored layer, each a fixed-capacity columnar store with
time-horizon eviction. Ingest is vectorised end to end — a decoded wire batch
is split into per-layer masks and block-copied into the window columns; no
`Event` objects exist on the hot path.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.core.events import NAME_DT, NAME_WIDTH, Layer
from repro.stream import wire

# columns every window keeps (name dtype is fixed-width so the store is flat)
_F64 = ("ts", "dur", "size") + wire.TELEMETRY_KEYS
_NAME_DT = NAME_DT


class LayerWindow:
    """Fixed-capacity sliding window of one layer's events, columnar.

    Rows live in preallocated arrays `[0, n)`; appends block-copy into the
    tail, overflow and horizon eviction compact in place. Rows are kept in
    arrival order (per-node batches are time-sorted; cross-node interleaving
    is only approximately sorted, so eviction uses a mask, not a tail
    pointer).
    """

    def __init__(self, layer: Layer, capacity: int = 65536,
                 horizon_s: float = 60.0):
        self.layer = layer
        self.capacity = int(capacity)
        self.horizon_s = float(horizon_s)
        self.n = 0
        self.evicted = 0  # rows dropped (horizon or overflow) over lifetime
        self.names_truncated = 0  # names clipped to the fixed width
        self.cols: Dict[str, np.ndarray] = {
            k: np.zeros(self.capacity, dtype=np.float64) for k in _F64}
        self.cols["step"] = np.zeros(self.capacity, dtype=np.int64)
        self.cols["node"] = np.zeros(self.capacity, dtype=np.int32)
        self.cols["name"] = np.zeros(self.capacity, dtype=_NAME_DT)

    def __len__(self) -> int:
        return self.n

    # -- mutation -------------------------------------------------------------
    def append(self, cols: Dict[str, np.ndarray], node_id: int,
               sel: Optional[np.ndarray] = None) -> int:
        """Block-copy rows from a wire-format column dict (optionally the
        subset selected by boolean mask ``sel``). Returns rows added."""

        def pick(key: str) -> np.ndarray:
            c = cols[key]
            return c[sel] if sel is not None else c

        ts = pick("ts")
        n_add = int(ts.shape[0])
        if n_add == 0:
            return 0
        if n_add > self.capacity:  # keep only the newest capacity rows
            self.evicted += n_add - self.capacity
            keep = np.argsort(ts, kind="stable")[n_add - self.capacity:]
            sel = keep if sel is None else np.flatnonzero(sel)[keep]
            ts = cols["ts"][sel]
            n_add = self.capacity
        if self.n + n_add > self.capacity:
            self._make_room(self.n + n_add - self.capacity)
        lo, hi = self.n, self.n + n_add
        for k in _F64:
            self.cols[k][lo:hi] = pick(k)
        self.cols["step"][lo:hi] = pick("step")
        incoming = pick("name")
        if incoming.dtype.itemsize > 4 * NAME_WIDTH:
            # assignment into the fixed-width store clips: count, don't hide
            self.names_truncated += int(
                (np.char.str_len(incoming) > NAME_WIDTH).sum())
        self.cols["name"][lo:hi] = incoming
        self.cols["node"][lo:hi] = node_id
        self.n = hi
        return n_add

    def _make_room(self, n_drop: int) -> None:
        """Drop the n_drop oldest rows (by ts) via in-place compaction."""
        order = np.argsort(self.cols["ts"][:self.n], kind="stable")
        keep = np.sort(order[n_drop:])
        self._compact(keep)
        self.evicted += n_drop

    def evict_older_than(self, cutoff_ts: float) -> int:
        """Horizon eviction: drop rows with ts < cutoff. Returns rows
        dropped."""
        if self.n == 0:
            return 0
        keep = np.flatnonzero(self.cols["ts"][:self.n] >= cutoff_ts)
        dropped = self.n - keep.shape[0]
        if dropped:
            self._compact(keep)
            self.evicted += dropped
        return dropped

    def _compact(self, keep: np.ndarray) -> None:
        for k, col in self.cols.items():
            col[:keep.shape[0]] = col[keep]
        self.n = int(keep.shape[0])

    # -- views ----------------------------------------------------------------
    def view(self) -> Dict[str, np.ndarray]:
        """Zero-copy views of the live rows (invalidated by mutation)."""
        return {k: col[:self.n] for k, col in self.cols.items()}

    def freeze(self) -> "SnapshotWindow":
        """Owned copy of the live rows, safe to read from another thread
        while this window keeps mutating. The async detection plane hands
        these to the executor — a zero-copy ``view()`` would tear the moment
        ingest compacts or appends under it.

        ``n`` is read once: `append` publishes new rows before bumping
        ``n``, so a single read yields a consistent prefix even if an append
        races this copy (compaction still requires freeze and ingest to
        share a thread, which the session's step loop guarantees)."""
        n = self.n
        return SnapshotWindow(self.layer,
                              {k: col[:n].copy()
                               for k, col in self.cols.items()})

    @property
    def t_newest(self) -> float:
        return float(self.cols["ts"][:self.n].max()) if self.n else 0.0


class SnapshotWindow:
    """Immutable point-in-time copy of a LayerWindow (duck-compatible with
    the read surface the detector uses: layer / __len__ / view())."""

    __slots__ = ("layer", "cols", "n")

    def __init__(self, layer: Layer, cols: Dict[str, np.ndarray]):
        self.layer = layer
        self.cols = cols
        self.n = int(cols["ts"].shape[0]) if cols else 0

    def __len__(self) -> int:
        return self.n

    def view(self) -> Dict[str, np.ndarray]:
        return self.cols

    @property
    def t_newest(self) -> float:
        return float(self.cols["ts"].max()) if self.n else 0.0


class FleetAggregator:
    """Merges wire batches from N nodes into per-layer sliding windows."""

    LAYERS = tuple(Layer)
    MISSING_SEQ_CAP = 512  # outstanding seq gaps remembered per node

    def __init__(self, capacity_per_layer: int = 65536,
                 horizon_s: float = 60.0):
        self.horizon_s = float(horizon_s)
        self.windows: Dict[Layer, LayerWindow] = {
            layer: LayerWindow(layer, capacity_per_layer, horizon_s)
            for layer in self.LAYERS}
        self.nodes_seen: Dict[int, int] = {}  # node_id -> newest seq seen
        # seq gaps counted into lost_batches that a late delivery may still
        # fill (bounded per node; overflow stays counted as lost)
        self._missing_seqs: Dict[int, set] = {}
        self.lost_batches = 0
        self.events_ingested = 0
        self.events_dropped_at_source = 0
        self.events_shed_at_source = 0
        self.t_latest = 0.0
        # node_id -> fleet-clock ts of the node's newest ingested event.
        # Freshness = t_latest - node_last_ts[n]: event-time, so a node
        # whose agent stops flushing goes stale as soon as the REST of the
        # fleet advances the clock past it (no wall-clock dependency).
        self.node_last_ts: Dict[int, float] = {}

    def ingest(self, batch: Union[bytes, wire.EventBatch]) -> int:
        """Merge one node flush; returns events added across layers."""
        if isinstance(batch, (bytes, bytearray, memoryview)):
            batch = wire.decode(bytes(batch))
        nid = batch.node_id
        last = self.nodes_seen.get(nid)
        if last is None or batch.seq == last + 1:
            self.nodes_seen[nid] = batch.seq
        elif batch.seq > last + 1:
            # gap: count it lost, but remember WHICH seqs are outstanding so
            # an out-of-order late delivery uncounts itself instead of
            # flipping a healthy node's accounting
            missing = self._missing_seqs.setdefault(nid, set())
            missing.update(range(last + 1, batch.seq))
            self.lost_batches += batch.seq - last - 1
            while len(missing) > self.MISSING_SEQ_CAP:
                missing.discard(min(missing))  # oldest gaps stay counted
            self.nodes_seen[nid] = batch.seq
        else:
            # late or duplicate arrival: seq <= newest seen. A late batch
            # that fills a counted gap is a delivery, not a loss.
            missing = self._missing_seqs.get(nid)
            if missing and batch.seq in missing:
                missing.discard(batch.seq)
                self.lost_batches -= 1
        self.events_dropped_at_source += batch.dropped
        self.events_shed_at_source += batch.shed
        cols = batch.columns
        n = int(cols["ts"].shape[0])
        if n == 0:
            return 0
        layer_codes = cols["layer"]
        added = 0
        for code, layer in enumerate(self.LAYERS):
            sel = layer_codes == np.int8(code)
            if not sel.any():
                continue
            added += self.windows[layer].append(cols, batch.node_id, sel=sel)
        self.events_ingested += added
        t_max = float(cols["ts"].max())
        self.t_latest = max(self.t_latest, t_max)
        self.node_last_ts[batch.node_id] = max(
            self.node_last_ts.get(batch.node_id, -np.inf), t_max)
        return added

    def evict(self, now: Optional[float] = None) -> int:
        """Advance the horizon on every window; returns rows dropped."""
        cutoff = (self.t_latest if now is None else now) - self.horizon_s
        return sum(w.evict_older_than(cutoff) for w in self.windows.values())

    def window(self, layer: Layer) -> LayerWindow:
        return self.windows[layer]

    def freeze(self) -> "AggSnapshot":
        """Owned point-in-time copy of every layer window + the clock/
        membership facts detection publishing needs (duck-compatible with
        the aggregator surface `OnlineGMMDetector` reads). Taken on the
        ingest thread; read on the detection executor's worker."""
        return AggSnapshot(
            windows={layer: w.freeze() for layer, w in self.windows.items()},
            t_latest=self.t_latest,
            nodes_seen=dict(self.nodes_seen),
            node_last_ts=dict(self.node_last_ts))

    def stats(self) -> Dict[str, object]:
        return {
            "nodes": len(self.nodes_seen),
            "events_ingested": self.events_ingested,
            "events_dropped_at_source": self.events_dropped_at_source,
            "events_shed_at_source": self.events_shed_at_source,
            "lost_batches": self.lost_batches,
            # names clipped to the fixed column width on ingest — nonzero
            # means kernel names in traces/reports are prefixes
            "names_truncated": sum(w.names_truncated
                                   for w in self.windows.values()),
            "window_sizes": {l.value: len(w) for l, w in self.windows.items()
                             if len(w)},
            "t_latest": self.t_latest,
        }


class AggSnapshot:
    """Frozen FleetAggregator read surface for off-thread detection."""

    __slots__ = ("windows", "t_latest", "nodes_seen", "node_last_ts")

    def __init__(self, windows: Dict[Layer, SnapshotWindow], t_latest: float,
                 nodes_seen: Dict[int, int], node_last_ts: Dict[int, float]):
        self.windows = windows
        self.t_latest = t_latest
        self.nodes_seen = nodes_seen
        self.node_last_ts = node_last_ts

    def window(self, layer: Layer) -> SnapshotWindow:
        return self.windows[layer]
