"""Compact columnar wire format for event batches.

Node agents ship drained event-table contents to the fleet aggregator as
*columns*, not objects: one contiguous buffer per field, preceded by a small
JSON header. Since the columnar redesign the drained `EventTable` columns ARE
the wire schema — encoding is O(columns) buffer copies with no per-event
Python work at all, and the receiver ingests the columns straight into its
preallocated sliding windows without ever materialising `Event` objects.

Layout (little-endian), shared by every version:

    MAGIC "EACS" | u16 version | u32 header_len | header JSON (utf-8)
    | column block 0 | column block 1 | ...

Versions (all constants live HERE and nowhere else):

* **v1/v2 (plain)** — every column travels as raw fixed-dtype bytes; the
  header records node_id / seq / t_base / dropped / shed plus, per column,
  the dtype string and shape needed to reinterpret the bytes. String columns
  travel as fixed-width unicode (``<U#``): ~125 B/event, trivially seekable.
  v1 and v2 share the layout byte for byte (v2 merely added the ``shed``
  header field, which v1 readers never emitted); both decode identically.
* **v3 (compressed, the default)** — the fleet-scale encoding. Per batch:
  the ``<U64`` name column is dictionary-encoded (unique names once in the
  header, narrow uint codes on the wire), timestamps are quantised to
  integer nanoseconds and shipped as first-value + narrowed deltas
  (reconstruction error ≤ 0.5 ns per event, non-accumulating), integer
  columns (pid/tid/step) are min-offset narrowed or elided when constant,
  device telemetry (util/mem_gb/power_w/temp_c) ships sparsely — explicit
  row indices plus values only for rows that carry any — and the ``meta``
  column rides in the header as (index, value) pairs, absent when all-empty.
  Typical batches land at 20-30 B/event, a >4x reduction over plain.

Clips past ``events.NAME_WIDTH`` are *counted*, never silent — see
`EventTable.names_truncated` / `LayerWindow.names_truncated`; the v3
dictionary preserves natural-width names end to end exactly like plain.

``shed`` accounts events the node-side backpressure governor sampled OUT of
the batch before encoding (see `repro.fleet.governor`); receivers surface it
so no shed event is ever silent.
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# Columnar conversion + schema constants live with the event model now;
# re-exported here because this module was their original home.
from repro.core.events import (LAYER_CODE, LAYERS, TELEMETRY_KEYS,  # noqa: F401
                               Event, Layer, columns_to_events, empty_arrays,
                               empty_columns, events_to_arrays,
                               events_to_columns)

MAGIC = b"EACS"

# -- wire versions (single source of truth) ---------------------------------
VERSION_LEGACY = 1      # original plain layout (pre-shed header)
VERSION_PLAIN = 2       # plain layout + shed accounting in the header
VERSION_COMPRESSED = 3  # dictionary names + delta timestamps + sparse cols
SUPPORTED_VERSIONS: Tuple[int, ...] = (
    VERSION_LEGACY, VERSION_PLAIN, VERSION_COMPRESSED)
VERSION = VERSION_COMPRESSED  # default encode version

# wire columns in serialization order
WIRE_COLUMNS = ("layer", "name", "ts", "dur", "size", "pid", "tid", "step",
                "util", "mem_gb", "power_w", "temp_c", "meta")

# v3: integer columns that get min-offset narrowing / constant elision
_V3_INT_COLS = ("pid", "tid", "step")
# v3: float columns kept raw at full precision (detector features)
_V3_RAW_F64 = ("dur", "size")

_TS_SCALE = 1e9  # v3 timestamps quantise to integer nanoseconds


class WireVersionError(ValueError):
    """Decoded batch speaks a wire version this build does not support."""

    def __init__(self, got: int, supported: Sequence[int] = SUPPORTED_VERSIONS):
        supported = tuple(supported)
        super().__init__(
            f"wire version mismatch: batch has version {got}, this build "
            f"supports versions {', '.join(map(str, supported))} only — "
            f"re-encode the batch or upgrade the peer")
        self.got = got
        self.supported = supported


@dataclasses.dataclass
class EventBatch:
    """One flush from one node: columnar events + provenance."""

    node_id: int
    seq: int  # per-node flush counter (gaps => lost batches)
    # provenance only: the node epoch offset the agent ALREADY added to the
    # ts column before shipping (ts values arrive fleet-absolute; receivers
    # must not re-apply t_base)
    t_base: float
    columns: Dict[str, np.ndarray]
    dropped: int = 0  # ring-buffer overwrites since the previous flush
    shed: int = 0  # events the backpressure governor sampled out pre-encode

    def __len__(self) -> int:
        return int(self.columns["ts"].shape[0])

    def nbytes(self) -> int:
        return sum(int(c.nbytes) for c in self.columns.values())


def _wire_ready(col: np.ndarray) -> np.ndarray:
    """Fixed-dtype, contiguous view of a column for raw serialization.

    EventTable stores the ``meta`` column as object dtype (variable-length
    JSON strings); on the wire it becomes fixed-width unicode."""
    if col.dtype == object:
        col = col.astype(str) if col.shape[0] else np.empty(0, "<U1")
        if col.dtype.itemsize == 0:  # all-empty strings -> <U0 is unportable
            col = col.astype("<U1")
    return np.ascontiguousarray(col)


def _header_dict(batch: EventBatch) -> Dict[str, Any]:
    return {"node_id": batch.node_id, "seq": batch.seq,
            "t_base": batch.t_base, "dropped": batch.dropped,
            "shed": batch.shed}


def _frame(version: int, header: Dict[str, Any],
           parts: List[bytes]) -> bytes:
    hjson = json.dumps(header, separators=(",", ":")).encode()
    return b"".join([MAGIC, struct.pack("<HI", version, len(hjson)), hjson]
                    + parts)


# ---------------------------------------------------------------------------
# plain layout (v1/v2)
# ---------------------------------------------------------------------------


def _encode_plain(batch: EventBatch, version: int) -> bytes:
    parts: List[bytes] = []
    colspec = []
    for name in WIRE_COLUMNS:
        col = _wire_ready(batch.columns[name])
        raw = col.tobytes()
        colspec.append({"name": name, "dtype": col.dtype.str,
                        "n": int(col.shape[0]), "nbytes": len(raw)})
        parts.append(raw)
    header = _header_dict(batch)
    header["columns"] = colspec
    return _frame(version, header, parts)


def _decode_plain(header: Dict[str, Any], buf: bytes,
                  off: int) -> Dict[str, np.ndarray]:
    columns: Dict[str, np.ndarray] = {}
    for spec in header["columns"]:
        nbytes = spec["nbytes"]
        raw = buf[off:off + nbytes]
        if len(raw) != nbytes:
            raise ValueError(f"truncated column {spec['name']}: "
                             f"{len(raw)}/{nbytes} bytes")
        arr = np.frombuffer(raw, dtype=np.dtype(spec["dtype"]))
        if arr.shape[0] != spec["n"]:
            raise ValueError(f"column {spec['name']} length mismatch")
        columns[spec["name"]] = arr
        off += nbytes
    return columns


# ---------------------------------------------------------------------------
# compressed layout (v3)
# ---------------------------------------------------------------------------


def _narrow_uint(values: np.ndarray) -> Tuple[np.ndarray, int]:
    """Min-offset unsigned narrowing: values -> (narrow offsets, base)."""
    base = int(values.min()) if values.shape[0] else 0
    span = int(values.max()) - base if values.shape[0] else 0
    for dt in (np.uint8, np.uint16, np.uint32):
        if span <= np.iinfo(dt).max:
            return (values - base).astype(dt), base
    return (values - base).astype(np.uint64), base


def _encode_compressed(batch: EventBatch) -> bytes:
    cols = batch.columns
    n = int(cols["ts"].shape[0])
    header = _header_dict(batch)
    header["n"] = n
    colspec: List[Dict[str, Any]] = []
    parts: List[bytes] = []

    def block(spec: Dict[str, Any], arr: Optional[np.ndarray]) -> None:
        raw = arr.tobytes() if arr is not None else b""
        if arr is not None:
            spec["block"] = arr.dtype.str
        spec["nbytes"] = len(raw)
        colspec.append(spec)
        parts.append(raw)

    if n:
        # layer: raw int8
        layer = np.ascontiguousarray(cols["layer"], dtype=np.int8)
        block({"name": "layer", "enc": "raw", "dtype": "|i1", "n": n}, layer)

        # name: per-batch dictionary, narrow uint codes on the wire
        names_fw = _wire_ready(cols["name"])
        uniq, codes = np.unique(names_fw, return_inverse=True)
        header["names"] = [str(s) for s in uniq]
        codes_arr, _ = _narrow_uint(codes.astype(np.int64))
        block({"name": "name", "enc": "dict", "dtype": names_fw.dtype.str,
               "n": n}, codes_arr)

        # ts: integer-nanosecond quantisation, first value + narrowed deltas
        ts_ns = np.round(np.asarray(cols["ts"], np.float64)
                         * _TS_SCALE).astype(np.int64)
        diffs = np.diff(ts_ns)
        packed, base = _narrow_uint(diffs)
        block({"name": "ts", "enc": "delta", "dtype": "<f8", "n": n,
               "first": int(ts_ns[0]), "base": base}, packed)

        # dur/size: full-precision floats (detector features). Many batches
        # carry few distinct values (tensor sizes, zero durations) — dict-
        # encode when that wins, raw f8 otherwise; precision is exact either
        # way.
        for key in _V3_RAW_F64:
            arr = np.ascontiguousarray(cols[key], dtype=np.float64)
            uniq, codes = np.unique(arr, return_inverse=True)
            if (uniq.shape[0] <= 256 and uniq.shape[0] * 4 <= n
                    and not np.isnan(uniq).any()):
                codes_arr, _ = _narrow_uint(codes.astype(np.int64))
                block({"name": key, "enc": "fdict", "dtype": "<f8", "n": n,
                       "n_dict": int(uniq.shape[0])},
                      np.concatenate([uniq.view(np.uint8),
                                      codes_arr.view(np.uint8)]))
                colspec[-1]["block"] = codes_arr.dtype.str
            else:
                block({"name": key, "enc": "raw", "dtype": "<f8", "n": n},
                      arr)

        # pid/tid/step: constant elision, else min-offset narrowing
        for key in _V3_INT_COLS:
            ints = np.asarray(cols[key], np.int64)
            lo, hi = int(ints.min()), int(ints.max())
            if lo == hi:
                block({"name": key, "enc": "const", "dtype": "<i8", "n": n,
                       "value": lo}, None)
            else:
                packed, base = _narrow_uint(ints)
                block({"name": key, "enc": "minoff", "dtype": "<i8", "n": n,
                       "base": base}, packed)

        # telemetry: one shared index of rows carrying ANY telemetry, then
        # values-at-index per column (device events are a small fraction)
        tele = np.stack([np.asarray(cols[k], np.float64)
                         for k in TELEMETRY_KEYS])
        idx = np.flatnonzero(~np.isnan(tele).all(axis=0))
        idx_arr, idx_base = _narrow_uint(idx.astype(np.int64))
        block({"name": "__rows__", "enc": "index", "n": int(idx.shape[0]),
               "base": idx_base}, idx_arr)
        for j, key in enumerate(TELEMETRY_KEYS):
            block({"name": key, "enc": "sparse", "dtype": "<f8", "n": n},
                  np.ascontiguousarray(tele[j, idx]))

        # meta: (row, value) pairs in the header, absent when all-empty
        meta = cols["meta"]
        if meta.dtype == object:
            nonempty = [(i, str(v)) for i, v in enumerate(meta) if v]
        else:
            midx = np.flatnonzero(np.char.str_len(meta.astype(str)))
            nonempty = [(int(i), str(meta[i])) for i in midx]
        if nonempty:
            header["meta"] = {"idx": [i for i, _ in nonempty],
                              "vals": [v for _, v in nonempty]}

    header["columns"] = colspec
    return _frame(VERSION_COMPRESSED, header, parts)


def _decode_compressed(header: Dict[str, Any], buf: bytes,
                       off: int) -> Dict[str, np.ndarray]:
    n = int(header.get("n", 0))
    if n == 0:
        return empty_columns()
    names = header.get("names")
    if not isinstance(names, list):
        raise ValueError("corrupt wire header: missing name dictionary")
    columns: Dict[str, np.ndarray] = {}
    tele_idx: Optional[np.ndarray] = None
    for spec in header["columns"]:
        nbytes = spec["nbytes"]
        raw = buf[off:off + nbytes]
        if len(raw) != nbytes:
            raise ValueError(f"truncated column {spec['name']}: "
                             f"{len(raw)}/{nbytes} bytes")
        off += nbytes
        enc = spec.get("enc")
        blk = (np.frombuffer(raw, dtype=np.dtype(spec["block"]))
               if "block" in spec else np.empty(0, np.int64))
        if enc == "raw":
            if blk.shape[0] != spec["n"]:
                raise ValueError(f"column {spec['name']} length mismatch")
            columns[spec["name"]] = blk
        elif enc == "dict":
            codes = blk.astype(np.int64)
            if codes.shape[0] != spec["n"]:
                raise ValueError(f"column {spec['name']} length mismatch")
            if codes.shape[0] and int(codes.max()) >= len(names):
                raise ValueError(
                    f"corrupt name dictionary: code {int(codes.max())} out "
                    f"of range (dictionary has {len(names)} entries)")
            columns[spec["name"]] = np.array(
                names, dtype=spec["dtype"])[codes]
        elif enc == "delta":
            if blk.shape[0] != spec["n"] - 1:
                raise ValueError(f"column {spec['name']} length mismatch")
            ts_ns = np.empty(spec["n"], np.int64)
            ts_ns[0] = int(spec["first"])
            np.cumsum(blk.astype(np.int64) + int(spec["base"]),
                      out=ts_ns[1:])
            ts_ns[1:] += ts_ns[0]
            columns[spec["name"]] = (ts_ns / _TS_SCALE).astype(
                np.dtype(spec["dtype"]))
        elif enc == "fdict":
            nd = int(spec["n_dict"])
            values = np.frombuffer(raw[:nd * 8], dtype="<f8")
            codes = np.frombuffer(raw[nd * 8:],
                                  dtype=np.dtype(spec["block"]))
            if values.shape[0] != nd or codes.shape[0] != spec["n"]:
                raise ValueError(f"column {spec['name']} length mismatch")
            if codes.shape[0] and int(codes.max()) >= nd:
                raise ValueError(
                    f"corrupt value dictionary in {spec['name']}: code "
                    f"{int(codes.max())} out of range ({nd} entries)")
            columns[spec["name"]] = values[codes.astype(np.int64)]
        elif enc == "const":
            columns[spec["name"]] = np.full(
                spec["n"], spec["value"], dtype=np.dtype(spec["dtype"]))
        elif enc == "minoff":
            if blk.shape[0] != spec["n"]:
                raise ValueError(f"column {spec['name']} length mismatch")
            columns[spec["name"]] = (blk.astype(np.int64)
                                     + int(spec["base"])).astype(
                np.dtype(spec["dtype"]))
        elif enc == "index":
            tele_idx = blk.astype(np.int64) + int(spec.get("base", 0))
            if tele_idx.shape[0] != spec["n"]:
                raise ValueError("telemetry index length mismatch")
            if tele_idx.shape[0] and (int(tele_idx.max()) >= n
                                      or int(tele_idx.min()) < 0):
                raise ValueError("corrupt telemetry index: row out of range")
        elif enc == "sparse":
            if tele_idx is None:
                raise ValueError(
                    f"corrupt batch: sparse column {spec['name']} precedes "
                    "its telemetry index")
            if blk.shape[0] != tele_idx.shape[0]:
                raise ValueError(f"column {spec['name']} length mismatch")
            full = np.full(n, np.nan, dtype=np.dtype(spec["dtype"]))
            full[tele_idx] = blk
            columns[spec["name"]] = full
        else:
            raise ValueError(f"unknown column encoding {enc!r} "
                             f"for {spec['name']}")
    meta_spec = header.get("meta")
    if meta_spec:
        idx, vals = meta_spec["idx"], meta_spec["vals"]
        if len(idx) != len(vals) or (idx and (max(idx) >= n or min(idx) < 0)):
            raise ValueError("corrupt meta block: index out of range")
        width = max(1, max((len(v) for v in vals), default=1))
        meta = np.zeros(n, dtype=f"<U{width}")
        meta[np.asarray(idx, np.int64)] = vals
    else:
        meta = np.zeros(n, dtype="<U1")
    columns["meta"] = meta
    missing = [k for k in WIRE_COLUMNS if k not in columns]
    if missing:
        raise ValueError(f"corrupt batch: missing columns {missing}")
    return columns


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def encode(batch: EventBatch, version: Optional[int] = None) -> bytes:
    """EventBatch -> wire bytes (``version`` defaults to `VERSION`)."""
    version = VERSION if version is None else int(version)
    if version in (VERSION_LEGACY, VERSION_PLAIN):
        return _encode_plain(batch, version)
    if version == VERSION_COMPRESSED:
        return _encode_compressed(batch)
    raise WireVersionError(version)


def decode(buf: bytes) -> EventBatch:
    """Wire bytes -> EventBatch. Validates magic/version and column sizes.

    Raises `WireVersionError` on any version outside `SUPPORTED_VERSIONS`:
    the header layout beyond the version field is version-specific, so a
    mismatched parse would silently misread."""
    if buf[:4] != MAGIC:
        raise ValueError(f"bad magic {buf[:4]!r}")
    version, hlen = struct.unpack_from("<HI", buf, 4)
    if version not in SUPPORTED_VERSIONS:
        raise WireVersionError(version)
    off = 10
    hraw = buf[off:off + hlen]
    if len(hraw) != hlen:
        raise ValueError(f"truncated header: {len(hraw)}/{hlen} bytes")
    try:
        header = json.loads(hraw.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"corrupt wire header: {e}") from None
    off += hlen
    if version == VERSION_COMPRESSED:
        columns = _decode_compressed(header, buf, off)
    else:
        columns = _decode_plain(header, buf, off)
    return EventBatch(node_id=header["node_id"], seq=header["seq"],
                      t_base=header["t_base"], dropped=header["dropped"],
                      shed=header.get("shed", 0), columns=columns)


def encode_columns(cols: Dict[str, np.ndarray], *, node_id: int, seq: int,
                   t_base: float = 0.0, dropped: int = 0, shed: int = 0,
                   version: Optional[int] = None) -> bytes:
    """ColumnView -> wire bytes (the native path: no Event objects)."""
    return encode(EventBatch(node_id=node_id, seq=seq, t_base=t_base,
                             columns=cols, dropped=dropped, shed=shed),
                  version=version)


def encode_events(events: List[Event], *, node_id: int, seq: int,
                  t_base: float = 0.0, dropped: int = 0, shed: int = 0,
                  version: Optional[int] = None) -> bytes:
    """Convenience: Event list -> wire bytes in one call (compat path)."""
    return encode_columns(events_to_columns(events), node_id=node_id,
                          seq=seq, t_base=t_base, dropped=dropped, shed=shed,
                          version=version)
