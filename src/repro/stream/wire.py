"""Compact columnar wire format for event batches.

Node agents ship drained ring-buffer contents to the fleet aggregator as
*columns*, not objects: one contiguous buffer per field, preceded by a small
JSON header. Encoding N events costs O(columns) numpy copies (no per-event
Python work beyond the initial `events_to_arrays` columnarisation), and the
receiver can ingest the columns straight into its preallocated sliding
windows without ever materialising `Event` objects.

Layout (little-endian):

    MAGIC "EACS" | u16 version | u32 header_len | header JSON (utf-8)
    | column 0 bytes | column 1 bytes | ...

The header records node_id / seq / t_base / dropped plus, per column, the
dtype string and shape needed to reinterpret the raw bytes. String columns
travel as fixed-width unicode (``<U#``) — wasteful for long names but
trivially seekable; event names in this system are short symbol names.

Device-layer telemetry (util/mem_gb/power_w/temp_c, carried in ``Event.meta``)
is lifted into four dedicated float64 columns at encode time so the aggregator
never parses JSON per event; any *other* meta keys ride in an optional
JSON-lines column that is empty for typical batches.
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.events import Event, Layer, empty_arrays, events_to_arrays

MAGIC = b"EACS"
VERSION = 1

# Layer enum <-> wire code (int8). Order is the Layer declaration order and
# must stay append-only for cross-version compatibility.
LAYERS = tuple(Layer)
LAYER_CODE = {layer: np.int8(i) for i, layer in enumerate(LAYERS)}

# meta keys promoted to dedicated columns (device telemetry hot path)
TELEMETRY_KEYS = ("util", "mem_gb", "power_w", "temp_c")

# wire columns in serialization order
WIRE_COLUMNS = ("layer", "name", "ts", "dur", "size", "pid", "tid", "step",
                "util", "mem_gb", "power_w", "temp_c", "meta")


@dataclasses.dataclass
class EventBatch:
    """One flush from one node: columnar events + provenance."""

    node_id: int
    seq: int  # per-node flush counter (gaps => lost batches)
    # provenance only: the node epoch offset the agent ALREADY added to the
    # ts column before shipping (ts values arrive fleet-absolute; receivers
    # must not re-apply t_base)
    t_base: float
    columns: Dict[str, np.ndarray]
    dropped: int = 0  # ring-buffer overwrites since the previous flush

    def __len__(self) -> int:
        return int(self.columns["ts"].shape[0])

    def nbytes(self) -> int:
        return sum(int(c.nbytes) for c in self.columns.values())


def events_to_columns(events: List[Event]) -> Dict[str, np.ndarray]:
    """Extend the core columnar schema with wire-only columns: int8 layer
    codes, pid/tid, telemetry columns, and a JSON column for residual meta."""
    n = len(events)
    if n == 0:
        cols = {k: v for k, v in empty_arrays().items() if k != "layer"}
        cols.update({
            "layer": np.empty(0, dtype=np.int8),
            "pid": np.empty(0, dtype=np.int64),
            "tid": np.empty(0, dtype=np.int64),
            "meta": np.empty(0, dtype="<U1"),
        })
        for k in TELEMETRY_KEYS:
            cols[k] = np.empty(0, dtype=np.float64)
        return cols
    base = events_to_arrays(events)
    cols: Dict[str, np.ndarray] = {
        "layer": np.array([LAYER_CODE[e.layer] for e in events], dtype=np.int8),
        "name": base["name"],
        "ts": base["ts"],
        "dur": base["dur"],
        "size": base["size"],
        "pid": np.array([e.pid for e in events], dtype=np.int64),
        "tid": np.array([e.tid for e in events], dtype=np.int64),
        "step": base["step"],
    }
    for k in TELEMETRY_KEYS:
        cols[k] = np.array(
            [float((e.meta or {}).get(k, np.nan)) for e in events],
            dtype=np.float64)
    residual: List[str] = []
    for e in events:
        extra = {k: v for k, v in (e.meta or {}).items()
                 if k not in TELEMETRY_KEYS}
        residual.append(json.dumps(extra, separators=(",", ":"),
                                   default=str) if extra else "")
    cols["meta"] = np.array(residual)
    return cols


def columns_to_events(cols: Dict[str, np.ndarray]) -> List[Event]:
    """Inverse of events_to_columns (used by tests and trace export)."""
    out: List[Event] = []
    n = int(cols["ts"].shape[0])
    for i in range(n):
        meta: Optional[Dict[str, Any]] = None
        telemetry = {k: float(cols[k][i]) for k in TELEMETRY_KEYS
                     if not np.isnan(cols[k][i])}
        if telemetry:
            meta = telemetry
        raw = str(cols["meta"][i])
        if raw:
            meta = dict(meta or {}, **json.loads(raw))
        out.append(Event(
            layer=LAYERS[int(cols["layer"][i])],
            name=str(cols["name"][i]),
            ts=float(cols["ts"][i]),
            dur=float(cols["dur"][i]),
            size=float(cols["size"][i]),
            pid=int(cols["pid"][i]),
            tid=int(cols["tid"][i]),
            step=int(cols["step"][i]),
            meta=meta,
        ))
    return out


def encode(batch: EventBatch) -> bytes:
    """EventBatch -> wire bytes."""
    parts: List[bytes] = []
    colspec = []
    for name in WIRE_COLUMNS:
        col = np.ascontiguousarray(batch.columns[name])
        raw = col.tobytes()
        colspec.append({"name": name, "dtype": col.dtype.str,
                        "n": int(col.shape[0]), "nbytes": len(raw)})
        parts.append(raw)
    header = json.dumps({
        "node_id": batch.node_id, "seq": batch.seq,
        "t_base": batch.t_base, "dropped": batch.dropped,
        "columns": colspec,
    }, separators=(",", ":")).encode()
    return b"".join([MAGIC, struct.pack("<HI", VERSION, len(header)), header]
                    + parts)


def decode(buf: bytes) -> EventBatch:
    """Wire bytes -> EventBatch. Validates magic/version and column sizes."""
    if buf[:4] != MAGIC:
        raise ValueError(f"bad magic {buf[:4]!r}")
    version, hlen = struct.unpack_from("<HI", buf, 4)
    if version > VERSION:
        raise ValueError(f"wire version {version} newer than supported "
                         f"{VERSION}")
    off = 10
    header = json.loads(buf[off:off + hlen].decode())
    off += hlen
    columns: Dict[str, np.ndarray] = {}
    for spec in header["columns"]:
        nbytes = spec["nbytes"]
        raw = buf[off:off + nbytes]
        if len(raw) != nbytes:
            raise ValueError(f"truncated column {spec['name']}: "
                             f"{len(raw)}/{nbytes} bytes")
        arr = np.frombuffer(raw, dtype=np.dtype(spec["dtype"]))
        if arr.shape[0] != spec["n"]:
            raise ValueError(f"column {spec['name']} length mismatch")
        columns[spec["name"]] = arr
        off += nbytes
    return EventBatch(node_id=header["node_id"], seq=header["seq"],
                      t_base=header["t_base"], dropped=header["dropped"],
                      columns=columns)


def encode_events(events: List[Event], *, node_id: int, seq: int,
                  t_base: float = 0.0, dropped: int = 0) -> bytes:
    """Convenience: Event list -> wire bytes in one call."""
    return encode(EventBatch(node_id=node_id, seq=seq, t_base=t_base,
                             columns=events_to_columns(events),
                             dropped=dropped))
