"""Compact columnar wire format for event batches.

Node agents ship drained event-table contents to the fleet aggregator as
*columns*, not objects: one contiguous buffer per field, preceded by a small
JSON header. Since the columnar redesign the drained `EventTable` columns ARE
the wire schema — encoding is O(columns) buffer copies with no per-event
Python work at all, and the receiver ingests the columns straight into its
preallocated sliding windows without ever materialising `Event` objects.

Layout (little-endian):

    MAGIC "EACS" | u16 version | u32 header_len | header JSON (utf-8)
    | column 0 bytes | column 1 bytes | ...

The header records node_id / seq / t_base / dropped plus, per column, the
dtype string and shape needed to reinterpret the raw bytes. String columns
travel as fixed-width unicode (``<U#``) — wasteful for long names but
trivially seekable; event names in this system are short symbol names (and
clips past ``events.NAME_WIDTH`` are *counted*, never silent — see
`EventTable.names_truncated` / `LayerWindow.names_truncated`).

Device-layer telemetry (util/mem_gb/power_w/temp_c) lives in four dedicated
float64 columns end to end; any *other* metadata rides in a JSON-lines
column that is empty for typical batches.
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import Any, Dict, List, Optional

import numpy as np

# Columnar conversion + schema constants live with the event model now;
# re-exported here because this module was their original home.
from repro.core.events import (LAYER_CODE, LAYERS, TELEMETRY_KEYS,  # noqa: F401
                               Event, Layer, columns_to_events, empty_arrays,
                               empty_columns, events_to_arrays,
                               events_to_columns)

MAGIC = b"EACS"
VERSION = 1

# wire columns in serialization order
WIRE_COLUMNS = ("layer", "name", "ts", "dur", "size", "pid", "tid", "step",
                "util", "mem_gb", "power_w", "temp_c", "meta")


class WireVersionError(ValueError):
    """Decoded batch speaks a different wire version than this build."""

    def __init__(self, got: int, supported: int):
        super().__init__(
            f"wire version mismatch: batch has version {got}, this build "
            f"supports version {supported} only — re-encode the batch or "
            f"upgrade the peer")
        self.got = got
        self.supported = supported


@dataclasses.dataclass
class EventBatch:
    """One flush from one node: columnar events + provenance."""

    node_id: int
    seq: int  # per-node flush counter (gaps => lost batches)
    # provenance only: the node epoch offset the agent ALREADY added to the
    # ts column before shipping (ts values arrive fleet-absolute; receivers
    # must not re-apply t_base)
    t_base: float
    columns: Dict[str, np.ndarray]
    dropped: int = 0  # ring-buffer overwrites since the previous flush

    def __len__(self) -> int:
        return int(self.columns["ts"].shape[0])

    def nbytes(self) -> int:
        return sum(int(c.nbytes) for c in self.columns.values())


def _wire_ready(col: np.ndarray) -> np.ndarray:
    """Fixed-dtype, contiguous view of a column for raw serialization.

    EventTable stores the ``meta`` column as object dtype (variable-length
    JSON strings); on the wire it becomes fixed-width unicode."""
    if col.dtype == object:
        col = col.astype(str) if col.shape[0] else np.empty(0, "<U1")
        if col.dtype.itemsize == 0:  # all-empty strings -> <U0 is unportable
            col = col.astype("<U1")
    return np.ascontiguousarray(col)


def encode(batch: EventBatch) -> bytes:
    """EventBatch -> wire bytes."""
    parts: List[bytes] = []
    colspec = []
    for name in WIRE_COLUMNS:
        col = _wire_ready(batch.columns[name])
        raw = col.tobytes()
        colspec.append({"name": name, "dtype": col.dtype.str,
                        "n": int(col.shape[0]), "nbytes": len(raw)})
        parts.append(raw)
    header = json.dumps({
        "node_id": batch.node_id, "seq": batch.seq,
        "t_base": batch.t_base, "dropped": batch.dropped,
        "columns": colspec,
    }, separators=(",", ":")).encode()
    return b"".join([MAGIC, struct.pack("<HI", VERSION, len(header)), header]
                    + parts)


def decode(buf: bytes) -> EventBatch:
    """Wire bytes -> EventBatch. Validates magic/version and column sizes.

    Raises `WireVersionError` on ANY version mismatch (older or newer): the
    header layout beyond the version field is version-specific, so a
    mismatched struct unpack would silently misparse."""
    if buf[:4] != MAGIC:
        raise ValueError(f"bad magic {buf[:4]!r}")
    version, hlen = struct.unpack_from("<HI", buf, 4)
    if version != VERSION:
        raise WireVersionError(version, VERSION)
    off = 10
    header = json.loads(buf[off:off + hlen].decode())
    off += hlen
    columns: Dict[str, np.ndarray] = {}
    for spec in header["columns"]:
        nbytes = spec["nbytes"]
        raw = buf[off:off + nbytes]
        if len(raw) != nbytes:
            raise ValueError(f"truncated column {spec['name']}: "
                             f"{len(raw)}/{nbytes} bytes")
        arr = np.frombuffer(raw, dtype=np.dtype(spec["dtype"]))
        if arr.shape[0] != spec["n"]:
            raise ValueError(f"column {spec['name']} length mismatch")
        columns[spec["name"]] = arr
        off += nbytes
    return EventBatch(node_id=header["node_id"], seq=header["seq"],
                      t_base=header["t_base"], dropped=header["dropped"],
                      columns=columns)


def encode_columns(cols: Dict[str, np.ndarray], *, node_id: int, seq: int,
                   t_base: float = 0.0, dropped: int = 0) -> bytes:
    """ColumnView -> wire bytes (the native path: no Event objects)."""
    return encode(EventBatch(node_id=node_id, seq=seq, t_base=t_base,
                             columns=cols, dropped=dropped))


def encode_events(events: List[Event], *, node_id: int, seq: int,
                  t_base: float = 0.0, dropped: int = 0) -> bytes:
    """Convenience: Event list -> wire bytes in one call (compat path)."""
    return encode_columns(events_to_columns(events), node_id=node_id,
                          seq=seq, t_base=t_base, dropped=dropped)
