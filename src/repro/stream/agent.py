"""Per-node agent: periodically flushes a Collector's event table onto the
wire.

The agent is the node-resident half of the fleet monitor. It owns nothing but
a reference to the node's `Collector` (the eACGM daemon) and a flush counter;
each `flush()` drains the columnar event table, rebases timestamps onto the
fleet epoch, and returns a wire-encoded `EventBatch` — columns in, columns
out, zero `Event` objects. Dropped-event counts are carried per batch so the
aggregator can account for ring overruns (paper: bounded-memory perf
buffers) without trusting the stream to be complete.

At fleet scale the agent optionally runs a `BackpressureGovernor`
(`repro.fleet.governor`) on the agent→group path: when the group tier signals
pressure, the governor sheds load by stratified per-layer sampling BEFORE
encoding — never starving a layer, and stamping the shed count into the
batch header so the loss is accounted fleet-wide, not silent.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.collector import Collector
from repro.stream import wire


class NodeAgent:
    """Drains one node's collector into wire-format batches.

    ``ts_offset`` rebases node-local event timestamps (seconds since the
    collector's t0) onto a shared fleet clock; in a real deployment this is
    the node's NTP-disciplined epoch offset, in simulation it aligns the
    per-node monotonic clocks.

    ``governor`` (optional) is a `repro.fleet.governor.BackpressureGovernor`
    applied to every flush; ``wire_version`` selects the wire encoding
    (defaults to `wire.VERSION`, the compressed v3 format).
    """

    def __init__(self, node_id: int, collector: Collector,
                 ts_offset: float = 0.0, governor=None,
                 wire_version: Optional[int] = None):
        self.node_id = node_id
        self.collector = collector
        self.ts_offset = ts_offset
        self.governor = governor
        self.wire_version = (wire.VERSION if wire_version is None
                             else int(wire_version))
        self.seq = 0
        self.events_shipped = 0
        self.events_shed = 0  # sampled out by the governor, pre-encode
        self.bytes_shipped = 0
        self.encode_seconds = 0.0  # cumulative wire-encode wall time
        self._last_dropped = 0

    def flush(self) -> bytes:
        """Drain the event table and return one wire-encoded batch.

        Columnar end to end: the drained `EventTable` views ARE the wire
        columns — no `Event` objects are materialised."""
        cols = self.collector.drain_columns()
        if self.ts_offset and cols["ts"].shape[0]:
            cols["ts"] = cols["ts"] + self.ts_offset
        shed = 0
        if self.governor is not None and cols["ts"].shape[0]:
            cols, shed_by_layer = self.governor.admit(cols)
            shed = int(sum(shed_by_layer.values()))
            self.events_shed += shed
        total_dropped = self.collector.buffer.dropped
        batch = wire.EventBatch(
            node_id=self.node_id, seq=self.seq, t_base=self.ts_offset,
            columns=cols, dropped=total_dropped - self._last_dropped,
            shed=shed)
        self._last_dropped = total_dropped
        self.seq += 1
        t0 = time.perf_counter()
        buf = wire.encode(batch, version=self.wire_version)
        self.encode_seconds += time.perf_counter() - t0
        self.events_shipped += len(batch)
        self.bytes_shipped += len(buf)
        return buf

    def stats(self) -> dict:
        return {"node_id": self.node_id, "flushes": self.seq,
                "events_shipped": self.events_shipped,
                "events_shed": self.events_shed,
                "bytes_shipped": self.bytes_shipped,
                "encode_seconds": self.encode_seconds,
                "dropped_total": self._last_dropped,
                "wire_version": self.wire_version,
                "governor_budget": (self.governor.budget
                                    if self.governor is not None else None),
                # ring-level accounting straight from the collector: the
                # monitor's own loss/degradation is part of agent health
                "ring_dropped": self.collector.buffer.dropped,
                "names_truncated": self.collector.buffer.names_truncated}
