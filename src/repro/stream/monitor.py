"""StreamMonitor: the top-level streaming fleet monitor.

Composes the subsystem end to end:

    node Collector --NodeAgent.flush()--> wire bytes
        --FleetAggregator.ingest()--> per-layer sliding windows
        --OnlineGMMDetector.detect()--> per-window flags
        --IncidentEngine.update()--> ranked cross-node incidents

Batches always travel through the wire encoding, even in-process — the
simulated fleet exercises exactly the bytes a real multi-host deployment
would ship.

Driver contract (see launch/train.py --stream-monitor and
examples/fleet_demo.py):

    mon = StreamMonitor()
    mon.register_node(0, collector)
    ... run warmup steps ...
    mon.warmup()                  # fit baselines on the clean prefix
    ... each flush interval ...
    incidents = mon.tick()        # poll agents, detect, group incidents
    ... at shutdown ...
    incidents += mon.finish()
    print(mon.render_report())

Deprecated as a driver entry point: prefer `repro.session.Session` with a
``MonitorSpec(mode="stream")`` — the session drives this class and folds its
output into the unified `MonitorReport`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.collector import Collector
from repro.core.events import Event, Layer, export_perfetto
from repro.stream import wire
from repro.stream.agent import NodeAgent
from repro.stream.incidents import Incident, IncidentEngine
from repro.stream.online import OnlineGMMDetector, WindowDetection
from repro.stream.window import AggSnapshot, FleetAggregator


@dataclasses.dataclass
class SweepOutcome:
    """What a detection sweep computed off-thread, pending admission.

    Produced by ``detect_snapshot`` (any thread), consumed by ``admit``
    (step thread) — the hand-off boundary of the async detection plane.
    Everything incident-engine-facing stays out of the sweep: the engine is
    read by reporting on the step thread and is not thread-safe."""

    detections: Dict[Layer, WindowDetection]
    fitted: List[Layer]  # layers late-warmup fitted during this sweep
    t_latest: float  # snapshot fleet clock (floors + incident `now`)
    detect_s: float  # sweep wall time (compute only, excludes queueing)


def export_windows_trace(windows, path: str) -> str:
    """Perfetto export of the events currently held in per-layer sliding
    windows (flat monitor or merged fleet view — anything with `view()`).

    Bounded by the window horizon — a streaming monitor does not keep the
    whole run. Node ids are exported as pids so per-node tracks separate in
    the viewer."""
    events: List[Event] = []
    for layer, w in windows.items():
        v = w.view()
        for i in range(len(w)):
            meta = None
            if layer == Layer.DEVICE and not np.isnan(v["util"][i]):
                meta = {k: float(v[k][i]) for k in wire.TELEMETRY_KEYS}
            events.append(Event(
                layer=layer, name=str(v["name"][i]), ts=float(v["ts"][i]),
                dur=float(v["dur"][i]), size=float(v["size"][i]),
                step=int(v["step"][i]), pid=int(v["node"][i]), meta=meta))
    events.sort(key=lambda e: e.ts)
    return export_perfetto(events, path)


class StreamMonitor:
    def __init__(self, n_components: int = 3, contamination: float = 0.02,
                 horizon_s: float = 60.0, capacity_per_layer: int = 65536,
                 min_events: int = 64, incident_gap_s: float = 1.0,
                 incident_close_after_s: float = 2.0, min_flags: int = 8,
                 seed: int = 0, detector=None):
        self.aggregator = FleetAggregator(capacity_per_layer=capacity_per_layer,
                                          horizon_s=horizon_s)
        # any per-window detector with the OnlineGMMDetector surface
        # (warmup/warmed/detect/stats) slots in — see repro.stream.backends
        # for the pluggable model families; None = the GMM default
        self.detector = (detector if detector is not None
                         else OnlineGMMDetector(n_components=n_components,
                                                contamination=contamination,
                                                min_events=min_events,
                                                seed=seed))
        self.engine = IncidentEngine(gap_s=incident_gap_s,
                                     close_after_s=incident_close_after_s,
                                     min_flags=min_flags)
        self.agents: Dict[int, NodeAgent] = {}
        self.ticks = 0
        self.detect_seconds = 0.0  # cumulative detection wall time
        self.last_detect_ms = 0.0  # wall time of the most recent tick
        self.last_detections: Dict[Layer, WindowDetection] = {}
        # optional observer of every wire batch as it leaves an agent — the
        # session sink pipeline tees the transport through this
        self.wire_tap: Optional[Callable[[bytes], None]] = None

    # -- fleet membership -----------------------------------------------------
    def register_node(self, node_id: int, collector: Collector,
                      ts_offset: float = 0.0) -> NodeAgent:
        agent = NodeAgent(node_id, collector, ts_offset=ts_offset)
        self.agents[node_id] = agent
        return agent

    # -- pipeline stages ------------------------------------------------------
    def poll(self) -> int:
        """Flush every node agent through the wire into the aggregator."""
        added = 0
        for agent in self.agents.values():
            buf = agent.flush()
            if self.wire_tap is not None:
                self.wire_tap(buf)
            added += self.aggregator.ingest(buf)
        self.aggregator.evict()
        return added

    def warmup(self) -> List[Layer]:
        """Drain whatever the nodes have produced so far (assumed clean) and
        fit the per-layer models on it."""
        self.poll()
        fitted = self.detector.warmup(self.aggregator)
        self.engine.set_floor(self.aggregator.t_latest)
        return fitted

    def tick(self) -> List[Incident]:
        """One monitor cycle: poll, detect, group. Returns incidents closed
        by this cycle (the open one keeps accumulating)."""
        self.poll()
        if not self.detector.warmed:
            return []
        # late warmup: fit layers that lacked min_events at initial warmup
        # (e.g. slow device telemetry); their training window is excluded
        # from incident formation just like the initial one
        for layer in self.detector.warmup(self.aggregator):
            self.engine.set_layer_floor(layer, self.aggregator.t_latest)
        t0 = time.perf_counter()
        self.last_detections = self.detector.detect(self.aggregator)
        closed = self.engine.update(self.last_detections,
                                    now=self.aggregator.t_latest)
        dt = time.perf_counter() - t0
        self.detect_seconds += dt
        self.last_detect_ms = 1e3 * dt
        self.ticks += 1
        return closed

    # -- async trio (poll/freeze -> detect off-thread -> admit) ---------------
    # tick() == admit(detect_snapshot(snapshot())) when nothing ingests in
    # between; the async plane runs the middle call on the executor worker.

    def snapshot(self) -> Optional[AggSnapshot]:
        """Step-thread half of an async tick: poll agents, freeze the
        aggregator. Returns None before warmup (nothing to sweep)."""
        self.poll()
        if not self.detector.warmed:
            return None
        return self.aggregator.freeze()

    def detect_snapshot(self, snap: AggSnapshot) -> SweepOutcome:
        """Worker half: late-warmup + detect against a frozen snapshot.
        Touches only detector state — safe off-thread because the executor
        serialises sweeps per key."""
        t0 = time.perf_counter()
        fitted = self.detector.warmup(snap)
        detections = self.detector.detect(snap)
        return SweepOutcome(detections=detections, fitted=fitted,
                            t_latest=snap.t_latest,
                            detect_s=time.perf_counter() - t0)

    def admit(self, outcome: SweepOutcome) -> List[Incident]:
        """Step-thread half two: publish a sweep's results — late-warmup
        floors, incident engine update, tick accounting."""
        for layer in outcome.fitted:
            self.engine.set_layer_floor(layer, outcome.t_latest)
        self.last_detections = outcome.detections
        closed = self.engine.update(outcome.detections, now=outcome.t_latest)
        self.detect_seconds += outcome.detect_s
        self.last_detect_ms = 1e3 * outcome.detect_s
        self.ticks += 1
        return closed

    def finish(self) -> List[Incident]:
        """Final poll + force-close any open incident (end of run)."""
        incidents = self.tick()
        incidents += self.engine.flush()
        return incidents

    def export_trace(self, path: str) -> str:
        """Perfetto export of the events currently in the sliding windows.

        The agents drain the collectors' ring buffers, so the collector-side
        `export_trace` would be empty under streaming; this reconstructs the
        trace from the aggregated columns instead."""
        return export_windows_trace(self.aggregator.windows, path)

    # -- reporting ------------------------------------------------------------
    @property
    def incidents(self) -> List[Incident]:
        return self.engine.ranked()

    def render_report(self) -> str:
        agg = self.aggregator.stats()
        head = (f"fleet: {agg['nodes']} node(s), "
                f"{agg['events_ingested']} events ingested, "
                f"{agg['lost_batches']} lost batch(es), "
                f"{self.ticks} detection tick(s), "
                f"{1e3 * self.detect_seconds / max(self.ticks, 1):.1f} ms/tick")
        return head + "\n" + self.engine.render_report()

    def stats(self) -> Dict[str, object]:
        agents = {nid: a.stats() for nid, a in self.agents.items()}
        return {
            "aggregator": self.aggregator.stats(),
            "detector": self.detector.stats(),
            "agents": agents,
            "ticks": self.ticks,
            "detect_ms_per_tick":
                1e3 * self.detect_seconds / max(self.ticks, 1),
            "last_detect_ms": self.last_detect_ms,
            "incidents": len(self.engine.incidents),
            # monitor-side collection loss, aggregated across the fleet:
            # ring overwrites at the source + names clipped at the ring or
            # the aggregation windows (per-node detail stays under
            # "agents"; window-level detail under "aggregator")
            "events_dropped": sum(a["ring_dropped"]
                                  for a in agents.values()),
            "events_shed": sum(a["events_shed"] for a in agents.values()),
            "names_truncated": sum(a["names_truncated"]
                                   for a in agents.values())
            + self.aggregator.stats()["names_truncated"],
        }
